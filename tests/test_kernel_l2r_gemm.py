"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle.

The kernel is int32-exact, so assertions are bit-equality (the strongest
possible allclose).  interpret=True executes the kernel body on CPU.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.l2r_gemm import l2r_matmul_int, l2r_matmul_int_stacked
from repro.kernels.l2r_gemm import (int_gemm_ref, l2r_gemm,
                                    l2r_gemm_pallas_stacked, l2r_gemm_ref,
                                    l2r_gemm_ref_stacked, l2r_matmul_f)

SHAPES = [
    (128, 256, 128),   # exactly one block
    (256, 512, 256),   # multi-block every axis
    (64, 64, 64),      # smaller than a block (padding path)
    (130, 300, 77),    # ragged
    (1, 256, 128),     # single row
    (128, 32, 512),    # shallow K
]


@pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
@pytest.mark.parametrize("m,k,n", SHAPES)
def test_kernel_exact_int8(m, k, n, backend):
    rng = np.random.default_rng(m * 1000 + k + n)
    a = rng.integers(-128, 128, size=(m, k), dtype=np.int8)
    b = rng.integers(-128, 128, size=(k, n), dtype=np.int8)
    out = l2r_gemm(jnp.asarray(a), jnp.asarray(b), backend=backend)
    ref = int_gemm_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
@pytest.mark.parametrize("log2_radix", [1, 2, 4])
def test_kernel_radix_sweep(log2_radix, backend):
    rng = np.random.default_rng(42)
    a = rng.integers(-128, 128, size=(128, 256), dtype=np.int8)
    b = rng.integers(-128, 128, size=(256, 128), dtype=np.int8)
    out = l2r_gemm(jnp.asarray(a), jnp.asarray(b), log2_radix=log2_radix,
                   backend=backend)
    ref = int_gemm_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
@pytest.mark.parametrize("levels", list(range(1, 8)))
def test_kernel_progressive_levels_match_oracle(levels, backend):
    rng = np.random.default_rng(levels)
    a = rng.integers(-128, 128, size=(128, 256), dtype=np.int8)
    b = rng.integers(-128, 128, size=(256, 128), dtype=np.int8)
    out = l2r_gemm(jnp.asarray(a), jnp.asarray(b), levels=levels,
                   backend=backend)
    ref = l2r_gemm_ref(jnp.asarray(a), jnp.asarray(b), levels=levels)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_kernel_progressive_error_decreases():
    rng = np.random.default_rng(5)
    a = rng.integers(-128, 128, size=(128, 256), dtype=np.int8)
    b = rng.integers(-128, 128, size=(256, 128), dtype=np.int8)
    exact = np.asarray(int_gemm_ref(jnp.asarray(a), jnp.asarray(b)), np.int64)
    errs = []
    for lv in range(1, 8):
        out = np.asarray(l2r_gemm(jnp.asarray(a), jnp.asarray(b), levels=lv), np.int64)
        errs.append(np.abs(out - exact).max())
    assert errs[-1] == 0
    assert all(e1 >= e2 for e1, e2 in zip(errs, errs[1:]))


@pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
@pytest.mark.parametrize("n_bits,dtype", [(8, np.int8), (6, np.int8), (4, np.int8)])
def test_kernel_bitwidth_sweep(n_bits, dtype, backend):
    rng = np.random.default_rng(n_bits)
    lo, hi = -(1 << (n_bits - 1)), 1 << (n_bits - 1)
    a = rng.integers(lo, hi, size=(128, 256), dtype=dtype)
    b = rng.integers(lo, hi, size=(256, 128), dtype=dtype)
    out = l2r_gemm(jnp.asarray(a), jnp.asarray(b), n_bits=n_bits, log2_radix=2,
                   backend=backend)
    ref = int_gemm_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ------------------------------------------------- level-stacked schedule
def _rand_ints(rng, n_bits, shape):
    lo, hi = -(1 << (n_bits - 1)), 1 << (n_bits - 1)
    dt = np.int8 if n_bits <= 8 else np.int16
    return jnp.asarray(rng.integers(lo, hi, size=shape, dtype=dt))


@pytest.mark.parametrize("n_bits,log2_radix", [
    (8, 1), (8, 2), (8, 4), (6, 2), (4, 2), (4, 4), (16, 4),
])
def test_stacked_bit_identical_all_levels(n_bits, log2_radix):
    """The tentpole invariant: the level-stacked schedule is bit-identical
    to l2r_matmul_int for EVERY truncation depth, every radix, and
    non-block-multiple shapes."""
    rng = np.random.default_rng(n_bits * 10 + log2_radix)
    a = _rand_ints(rng, n_bits, (45, 67))   # ragged on purpose
    b = _rand_ints(rng, n_bits, (67, 31))
    d = n_bits // log2_radix
    for lv in [None] + list(range(1, 2 * d)):
        ref = np.asarray(l2r_matmul_int(a, b, n_bits, log2_radix, lv))
        out = np.asarray(l2r_matmul_int_stacked(a, b, n_bits, log2_radix, lv))
        np.testing.assert_array_equal(out, ref, err_msg=f"levels={lv}")


def test_stacked_levels_zero_matches_pair_loop():
    """Degenerate empty MSDF prefix: both schedules return zeros."""
    rng = np.random.default_rng(12)
    a = _rand_ints(rng, 8, (8, 16))
    b = _rand_ints(rng, 8, (16, 4))
    np.testing.assert_array_equal(
        np.asarray(l2r_matmul_int_stacked(a, b, levels=0)),
        np.asarray(l2r_matmul_int(a, b, levels=0)))
    np.testing.assert_array_equal(
        np.asarray(l2r_matmul_int_stacked(a, b, levels=0)), 0)


def test_core_l2r_dense_weight_cache_bit_identical():
    """core l2r_dense/l2r_matmul w_q threading (the non-dispatcher entry
    point used by e.g. MoE per-expert matmuls): cached == fresh, bitwise."""
    from repro.core.l2r_gemm import l2r_dense
    from repro.core.quant import QuantConfig, quantize_weights

    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((3, 5, 32)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((32, 10)) * 0.2).astype(np.float32))
    cfg = QuantConfig()
    w_q = quantize_weights(w, cfg)
    out_cached = np.asarray(l2r_dense(x, None, cfg, w_q=w_q))
    out_fresh = np.asarray(l2r_dense(x, w, cfg))
    np.testing.assert_array_equal(out_cached, out_fresh)


def test_stacked_ref_matches_pair_ref():
    rng = np.random.default_rng(11)
    a = _rand_ints(rng, 8, (37, 100))
    b = _rand_ints(rng, 8, (100, 53))
    for lv in (None, 2, 5):
        np.testing.assert_array_equal(
            np.asarray(l2r_gemm_ref_stacked(a, b, levels=lv)),
            np.asarray(l2r_gemm_ref(a, b, levels=lv)))


@pytest.mark.parametrize("levels", [None, 1, 4])
def test_stacked_pallas_kernel_bit_identical(levels):
    """Pallas stacked kernel (interpret) vs the core pair loop."""
    rng = np.random.default_rng(0 if levels is None else levels)
    a = _rand_ints(rng, 8, (128, 256))
    b = _rand_ints(rng, 8, (256, 128))
    out = np.asarray(l2r_gemm_pallas_stacked(a, b, levels=levels,
                                             interpret=True))
    ref = np.asarray(l2r_matmul_int(a, b, 8, 2, levels))
    np.testing.assert_array_equal(out, ref)


def test_stacked_pallas_multiblock_k():
    """K spanning multiple bk blocks exercises the scalar-prefetch walk."""
    rng = np.random.default_rng(7)
    a = _rand_ints(rng, 8, (128, 512))
    b = _rand_ints(rng, 8, (512, 128))
    out = np.asarray(l2r_gemm_pallas_stacked(a, b, bk=256, interpret=True))
    np.testing.assert_array_equal(out, np.asarray(int_gemm_ref(a, b)))


@pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
@pytest.mark.parametrize("schedule", ["stacked", "pairs"])
def test_dispatcher_backends_bit_identical(backend, schedule):
    """One ragged shape through every (backend, schedule) combination."""
    rng = np.random.default_rng(3)
    a = _rand_ints(rng, 8, (70, 90))
    b = _rand_ints(rng, 8, (90, 40))
    out = np.asarray(l2r_gemm(a, b, schedule=schedule, backend=backend))
    np.testing.assert_array_equal(out, np.asarray(int_gemm_ref(a, b)))


def test_dispatcher_env_override(monkeypatch):
    from repro.kernels.l2r_gemm import BACKEND_ENV_VAR, resolve_backend

    assert resolve_backend("jnp") == "jnp"
    monkeypatch.setenv(BACKEND_ENV_VAR, "pallas-interpret")
    assert resolve_backend() == "pallas-interpret"
    assert resolve_backend("jnp") == "jnp"  # explicit arg wins
    monkeypatch.setenv(BACKEND_ENV_VAR, "bogus")
    with pytest.raises(ValueError):
        resolve_backend()
    monkeypatch.delenv(BACKEND_ENV_VAR)
    # no TPU in this container -> platform default is the jnp schedule
    assert resolve_backend() == "jnp"


def test_float_wrapper_close_to_matmul():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((64, 256)).astype(np.float32)
    w = rng.standard_normal((256, 96)).astype(np.float32)
    out = np.asarray(l2r_matmul_f(jnp.asarray(x), jnp.asarray(w)))
    ref = x @ w
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert rel < 0.02, rel  # int8 W8A8 quantization error


def test_resolve_backend_pallas_tpu_off_platform(monkeypatch):
    """An explicit pallas-tpu on a non-TPU host fails AT RESOLVE TIME
    with an actionable message (previously: an opaque Mosaic lowering
    error deep inside the first pallas_call)."""
    from repro.kernels.l2r_gemm import BACKEND_ENV_VAR, resolve_backend

    # this container has no TPU — both the explicit arg and the env var
    # must be rejected before any kernel work happens
    with pytest.raises(RuntimeError, match="pallas-interpret"):
        resolve_backend("pallas-tpu")
    monkeypatch.setenv(BACKEND_ENV_VAR, "pallas-tpu")
    with pytest.raises(RuntimeError, match="pallas-interpret"):
        resolve_backend()
    with pytest.raises(RuntimeError, match="TPU"):
        l2r_gemm(jnp.zeros((8, 8), jnp.int8), jnp.zeros((8, 8), jnp.int8),
                 backend="pallas-tpu")


def test_pad_to_rank_mismatch_raises():
    """pad_to used to zip-truncate when len(mults) != ndim, silently
    leaving dims unpadded — now a ValueError both ways."""
    from repro.kernels.l2r_gemm import pad_to

    x = jnp.zeros((5, 7))
    out = np.asarray(pad_to(x, (4, 4)))
    assert out.shape == (8, 8)
    with pytest.raises(ValueError, match="rank"):
        pad_to(x, (4,))          # too few: trailing dim would go unpadded
    with pytest.raises(ValueError, match="rank"):
        pad_to(x, (4, 4, 4))     # too many: silent zip truncation before
    # rank-3 works when every dim is named (1 = keep)
    out = np.asarray(pad_to(jnp.zeros((2, 5, 7)), (1, 4, 4)))
    assert out.shape == (2, 8, 8)
