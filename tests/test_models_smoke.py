"""Per-assigned-architecture smoke tests (reduced configs, CPU).

Each arch: one forward pass (train mode) asserting output shapes and no
NaNs, plus one real optimizer step.  The FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.models.common import materialize
from repro.models.encdec import encdec_build, encdec_forward
from repro.models.transformer import lm_build, lm_forward, logits_from_hidden
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.step import TrainConfig, make_train_step


def _batch(cfg, b=2, s=16, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)), jnp.float32)
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    elif cfg.embeds_input:
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
        if cfg.rope_mode == "mrope":
            pos = np.tile(np.arange(s), (b, 1))
            batch["rope_positions"] = jnp.asarray(
                np.stack([pos, pos * 0, pos * 0]), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke(arch)
    rng = np.random.default_rng(1)
    batch = _batch(cfg, rng=rng)
    if cfg.family == "encdec":
        params = materialize(encdec_build(cfg), jax.random.PRNGKey(0))
        hidden, _, aux = encdec_forward(cfg, params, tokens=batch["tokens"],
                                        frames=batch["frames"], mode="train")
    else:
        params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
        hidden, _, aux = lm_forward(
            cfg, params, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            rope_positions=batch.get("rope_positions"), mode="train")
        logits = logits_from_hidden(cfg, params, hidden)
        assert logits.shape == (2, 16, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert hidden.shape == (2, 16, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    build = encdec_build if cfg.family == "encdec" else lm_build
    params = materialize(build(cfg), jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1),
                           TrainConfig(remat=False, seq_shard=False,
                                       xent_chunk=16))
    batch = _batch(cfg)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["loss"]) > 0
    assert int(opt2.step) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved, arch
