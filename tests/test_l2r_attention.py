"""Digit-serial attention: score-walk bit-exactness, the incrementally
plane-stacked KV cache, margin-bounded progressive decode, the dispatcher
entry, and the flash-fused level-walk kernel."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.l2r_attention import (attn_scores_stacked,
                                      attn_scores_streaming_scan,
                                      attn_scores_streaming_while,
                                      quantize_per_vector)
from repro.core.quant import PlaneOperands, QuantConfig, stack_planes_rhs
from repro.models.attention import (attn_exit_tap, decode_attention,
                                    init_kv_cache, kv_plane_operands,
                                    update_kv_cache)
from repro.models.common import materialize
from repro.models.transformer import lm_build
from repro.serve.engine import greedy_generate

CONFIGS = [(8, 2), (8, 4), (4, 2), (4, 1)]


def _rand_qk(rng, b=2, q=3, kv=2, g=2, s=7, dh=16, cfg=QuantConfig()):
    qf = jnp.asarray(rng.standard_normal((b, q, kv, g, dh)), jnp.float32)
    kf = jnp.asarray(rng.standard_normal((b, s, kv, dh)), jnp.float32)
    qq, _ = quantize_per_vector(qf, cfg)
    kq, _ = quantize_per_vector(kf, cfg)
    return qq, kq


# ------------------------------------------------------------- score walks
@pytest.mark.parametrize("n_bits,log2_radix", CONFIGS)
def test_stacked_scores_equal_int_einsum(n_bits, log2_radix):
    """Full-depth stacked scores == the exact int32 GQA einsum, for every
    digit config (the plane decomposition is exact)."""
    cfg = QuantConfig(n_bits=n_bits, log2_radix=log2_radix)
    qq, kq = _rand_qk(np.random.default_rng(0), cfg=cfg)
    ref = jnp.einsum("bqkgd,bskd->bkgqs", qq.astype(jnp.int32),
                     kq.astype(jnp.int32))
    out = attn_scores_stacked(qq, kq, n_bits, log2_radix)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("n_bits,log2_radix", CONFIGS)
def test_streaming_prefixes_bit_identical_to_truncated_stacked(
        n_bits, log2_radix):
    """Every streaming score prefix == the stacked schedule truncated at
    that level — the acceptance contract of the score walk."""
    cfg = QuantConfig(n_bits=n_bits, log2_radix=log2_radix)
    qq, kq = _rand_qk(np.random.default_rng(1), cfg=cfg)
    _, _, stack = attn_scores_streaming_scan(
        qq, kq, n_bits=n_bits, log2_radix=log2_radix, emit=True)
    for lvl in range(stack.shape[0]):
        tr = attn_scores_stacked(qq, kq, n_bits, log2_radix, levels=lvl + 1)
        np.testing.assert_array_equal(np.asarray(stack[lvl]), np.asarray(tr),
                                      err_msg=f"level {lvl}")


def test_while_walk_matches_scan_and_counts_levels():
    qq, kq = _rand_qk(np.random.default_rng(2))
    acc_s, _, _ = attn_scores_streaming_scan(qq, kq)
    acc_w, _, t = attn_scores_streaming_while(qq, kq)
    np.testing.assert_array_equal(np.asarray(acc_s), np.asarray(acc_w))
    assert int(t) == 2 * QuantConfig().planes - 1


def test_prestacked_operands_bit_identical():
    """Prepared PlaneOperands (incl. the cache's window-padded RHS) feed
    the walks bit-identically to inline extraction."""
    qq, kq = _rand_qk(np.random.default_rng(3))
    ref = attn_scores_stacked(qq, kq)
    q_po = PlaneOperands.prepare_lhs(qq, 8, 2)
    k_po = PlaneOperands.prepare_rhs(kq, 8, 2, axis=-1, window_pad=True)
    np.testing.assert_array_equal(np.asarray(attn_scores_stacked(q_po, k_po)),
                                  np.asarray(ref))
    acc, _, _ = attn_scores_streaming_scan(q_po, k_po)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(ref))


def test_levels_zero_is_empty_prefix():
    qq, kq = _rand_qk(np.random.default_rng(4))
    assert not np.any(np.asarray(attn_scores_stacked(qq, kq, levels=0)))


def test_mismatched_operand_raises_with_both_layouts():
    """A stack prepared for another digit config fails with BOTH operands'
    layouts in the message (satellite: actionable mismatch errors)."""
    qq, kq = _rand_qk(np.random.default_rng(5))
    q_po = PlaneOperands.prepare_lhs(qq, 8, 4)  # wrong radix for the call
    with pytest.raises(ValueError) as ei:
        attn_scores_stacked(q_po, kq, 8, 2)
    msg = str(ei.value)
    assert "PlaneOperands(side='lhs'" in msg and "log2_radix=4" in msg
    assert "other operand" in msg and "array(shape=" in msg


# -------------------------------------------- incrementally stacked KV cache
def test_incremental_plane_cache_bit_identical_to_reextraction():
    """Appending per-token digit planes reproduces, bit for bit, the stack
    (and scales) of re-extracting planes from the full float cache — the
    invariant that lets decode skip per-step K extraction."""
    rng = np.random.default_rng(6)
    cfg = QuantConfig()
    b, length, kvh, dh = 2, 12, 2, 16
    cache = init_kv_cache(b, length, kvh, dh, jnp.float32, quant=cfg)
    for t in range(9):
        kn = jnp.asarray(rng.standard_normal((b, 1, kvh, dh)), jnp.float32)
        vn = jnp.asarray(rng.standard_normal((b, 1, kvh, dh)), jnp.float32)
        pos = jnp.full((b, 1), t, jnp.int32)
        cache = update_kv_cache(cache, kn, vn, pos, quant=cfg)
    kq, ks = quantize_per_vector(cache.k, cfg)
    restack = stack_planes_rhs(kq, cfg.n_bits, cfg.log2_radix, axis=-1,
                               shifted=False)
    restack = jnp.pad(restack, ((0, 0), (0, 0), (0, 0),
                                (0, (cfg.planes - 1) * dh)))
    np.testing.assert_array_equal(np.asarray(cache.k_planes),
                                  np.asarray(restack))
    np.testing.assert_array_equal(np.asarray(cache.k_scale),
                                  np.asarray(ks[..., 0]))
    po = kv_plane_operands(cache, cfg)
    assert po.matches(cfg.n_bits, cfg.log2_radix, side="rhs")


def test_incremental_cache_chunk_independent():
    """One 9-token prefill append == nine 1-token decode appends."""
    rng = np.random.default_rng(7)
    cfg = QuantConfig()
    b, length, kvh, dh = 1, 12, 2, 8
    ks = jnp.asarray(rng.standard_normal((b, 9, kvh, dh)), jnp.float32)
    vs = jnp.asarray(rng.standard_normal((b, 9, kvh, dh)), jnp.float32)
    pos = jnp.asarray(np.arange(9)[None], jnp.int32)
    c_all = update_kv_cache(init_kv_cache(b, length, kvh, dh, jnp.float32,
                                          quant=cfg), ks, vs, pos, quant=cfg)
    c_one = init_kv_cache(b, length, kvh, dh, jnp.float32, quant=cfg)
    for t in range(9):
        c_one = update_kv_cache(c_one, ks[:, t:t + 1], vs[:, t:t + 1],
                                pos[:, t:t + 1], quant=cfg)
    np.testing.assert_array_equal(np.asarray(c_all.k_planes),
                                  np.asarray(c_one.k_planes))
    np.testing.assert_array_equal(np.asarray(c_all.k_scale),
                                  np.asarray(c_one.k_scale))


@pytest.mark.parametrize("window,g", [(None, 2), (4, 2), (None, 1)])
def test_decode_plane_cache_bit_identical_to_inline_quant(window, g):
    """decode_attention consuming the incremental plane cache == the same
    call re-quantizing the float cache, bit for bit, across GQA/window."""
    rng = np.random.default_rng(8)
    cfg = QuantConfig()
    b, length, kvh, dh = 2, 12, 2, 16
    h = kvh * g
    cache = init_kv_cache(b, length, kvh, dh, jnp.float32, quant=cfg)
    for t in range(9):
        kn = jnp.asarray(rng.standard_normal((b, 1, kvh, dh)), jnp.float32)
        vn = jnp.asarray(rng.standard_normal((b, 1, kvh, dh)), jnp.float32)
        cache = update_kv_cache(cache, kn, vn,
                                jnp.full((b, 1), t, jnp.int32), quant=cfg)
    q = jnp.asarray(rng.standard_normal((b, 1, h, dh)), jnp.float32)
    qpos = jnp.full((b,), 8, jnp.int32)
    out_inline = decode_attention(q, cache.k, cache.v, cache.positions, qpos,
                                  window=window, l2r=cfg)
    out_planes = decode_attention(q, cache.k, cache.v, cache.positions, qpos,
                                  window=window, l2r=cfg,
                                  k_planes=cache.k_planes,
                                  k_scale=cache.k_scale)
    np.testing.assert_array_equal(np.asarray(out_inline),
                                  np.asarray(out_planes))
    # and the quantized path tracks the float path to W8A8 noise
    out_f = decode_attention(q, cache.k, cache.v, cache.positions, qpos,
                             window=window)
    assert float(jnp.max(jnp.abs(out_planes - out_f))) < 0.1


# ------------------------------------------------ progressive decode (exit)
def test_early_exit_decode_bit_identical_at_tight_tol():
    rng = np.random.default_rng(9)
    cfg = QuantConfig()
    b, length, kvh, dh, g = 2, 12, 2, 16, 3
    cache = init_kv_cache(b, length, kvh, dh, jnp.float32, quant=cfg)
    for t in range(9):
        kn = jnp.asarray(rng.standard_normal((b, 1, kvh, dh)), jnp.float32)
        vn = jnp.asarray(rng.standard_normal((b, 1, kvh, dh)), jnp.float32)
        cache = update_kv_cache(cache, kn, vn,
                                jnp.full((b, 1), t, jnp.int32), quant=cfg)
    q = jnp.asarray(rng.standard_normal((b, 1, kvh * g, dh)), jnp.float32)
    qpos = jnp.full((b,), 8, jnp.int32)
    full = decode_attention(q, cache.k, cache.v, cache.positions, qpos,
                            l2r=cfg, k_planes=cache.k_planes,
                            k_scale=cache.k_scale)
    with attn_exit_tap() as rec:
        exited = decode_attention(q, cache.k, cache.v, cache.positions, qpos,
                                  l2r=cfg, k_planes=cache.k_planes,
                                  k_scale=cache.k_scale, early_exit=True,
                                  exit_tol=1e-4)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(exited))
    assert rec and rec[0]["exit_levels"].shape == (b, kvh, g)
    # a loose tolerance decides rows earlier, never later
    with attn_exit_tap() as rec2:
        decode_attention(q, cache.k, cache.v, cache.positions, qpos,
                         l2r=cfg, k_planes=cache.k_planes,
                         k_scale=cache.k_scale, early_exit=True,
                         exit_tol=10.0)
    assert (rec2[0]["exit_levels"] <= rec[0]["exit_levels"]).all()


def test_early_exit_rejects_softcap():
    rng = np.random.default_rng(10)
    cfg = QuantConfig()
    cache = init_kv_cache(1, 4, 1, 8, jnp.float32, quant=cfg)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 8)), jnp.float32)
    with pytest.raises(ValueError, match="softcap"):
        decode_attention(q, cache.k, cache.v, cache.positions,
                         jnp.zeros((1,), jnp.int32), softcap=30.0, l2r=cfg,
                         early_exit=True)


def test_early_exit_serving_tokens_match_full_depth():
    """Greedy decode with margin-bounded progressive attention commits the
    SAME tokens as the full-depth quantized path (acceptance criterion)."""
    cfg = get_smoke("smollm-135m")
    qc = QuantConfig()
    cfg_q = dataclasses.replace(cfg, attn_l2r=qc)
    cfg_e = dataclasses.replace(cfg_q, attn_early_exit=True,
                                attn_exit_tol=1e-4)
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    out_q = np.asarray(greedy_generate(cfg_q, params, prompt, steps=5))
    out_e = np.asarray(greedy_generate(cfg_e, params, prompt, steps=5))
    np.testing.assert_array_equal(out_q, out_e)


# --------------------------------------------------------------- dispatcher
def test_dispatcher_backends_and_schedules_bit_identical():
    from repro.kernels.l2r_gemm.ops import l2r_attn_scores
    qq, kq = _rand_qk(np.random.default_rng(12))
    ref = np.asarray(attn_scores_stacked(qq, kq))
    for kwargs in (dict(backend="jnp"),
                   dict(backend="jnp", schedule="streaming"),
                   dict(backend="jnp", schedule="streaming", early_exit=True),
                   dict(backend="pallas-interpret"),
                   dict(backend="pallas-interpret", schedule="streaming")):
        np.testing.assert_array_equal(
            np.asarray(l2r_attn_scores(qq, kq, **kwargs)), ref,
            err_msg=str(kwargs))
    np.testing.assert_array_equal(
        np.asarray(l2r_attn_scores(qq, kq, levels=3,
                                   backend="pallas-interpret")),
        np.asarray(attn_scores_stacked(qq, kq, levels=3)))


def test_dispatcher_rejections():
    from repro.kernels.l2r_gemm.ops import l2r_attn_scores
    qq, kq = _rand_qk(np.random.default_rng(13))
    with pytest.raises(ValueError, match="streaming"):
        l2r_attn_scores(qq, kq, early_exit=True, backend="jnp")
    with pytest.raises(ValueError, match="schedule"):
        l2r_attn_scores(qq, kq, schedule="pairs", backend="jnp")
    with pytest.raises(ValueError, match="while-loop emitter"):
        l2r_attn_scores(qq, kq, schedule="streaming", early_exit=True,
                        backend="pallas-interpret")


def test_gemm_mismatch_error_names_both_operands():
    """The enriched PlaneOperands mismatch raise (GEMM dispatcher site)."""
    from repro.kernels.l2r_gemm.ops import l2r_gemm
    rng = np.random.default_rng(14)
    a = jnp.asarray(rng.integers(-8, 8, (4, 8)), jnp.int8)
    b = jnp.asarray(rng.integers(-8, 8, (8, 4)), jnp.int8)
    a_po = PlaneOperands.prepare_lhs(a, 8, 4)
    with pytest.raises(ValueError) as ei:
        l2r_gemm(a_po, b, 8, 2)
    msg = str(ei.value)
    assert "log2_radix=4" in msg and "other operand" in msg


# -------------------------------------------------------- flash-fused kernel
def test_flash_attention_dispatch_default_is_oracle():
    """Satellite: the entry no longer defaults to interpret-mode Pallas —
    off-TPU it resolves to the jitted oracle, and an explicit pallas-tpu
    is rejected with the hinted error."""
    from repro.kernels.flash_attention import attention_ref, flash_attention
    rng = np.random.default_rng(15)
    q = jnp.asarray(rng.standard_normal((1, 16, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 16, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 16, 2, 8)), jnp.float32)
    if jax.default_backend() != "tpu":
        np.testing.assert_array_equal(
            np.asarray(flash_attention(q, k, v)),
            np.asarray(attention_ref(q, k, v, True, None, None)))
        with pytest.raises(RuntimeError, match="pallas-interpret"):
            flash_attention(q, k, v, backend="pallas-tpu")


def test_flash_l2r_kernel_matches_quantized_softmax_oracle():
    """ONE small interpret-mode run of the fused level-walk kernel vs the
    jnp quantized-score softmax (interpret mode is slow — keep it tiny)."""
    from repro.kernels.flash_attention import flash_attention_l2r_pallas
    rng = np.random.default_rng(16)
    b, s, h, kvh, dh = 1, 16, 2, 1, 8
    cfg = QuantConfig()
    q = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kvh, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kvh, dh)), jnp.float32)
    qq, qs = quantize_per_vector(q, cfg)
    kq, ks = quantize_per_vector(k, cfg)
    g = h // kvh
    s_int = attn_scores_stacked(qq.reshape(b, s, kvh, g, dh), kq)
    sc = (s_int.astype(jnp.float32)
          * qs.reshape(b, s, kvh, g, 1).transpose(0, 2, 3, 1, 4)
          * ks[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
          / np.sqrt(dh))
    pos = np.arange(s)
    mask = pos[None] <= pos[:, None]
    sc = jnp.where(jnp.asarray(mask)[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    ref = jnp.einsum("bkgqs,bskd->bqkgd", p, v).reshape(b, s, h, dh)
    out = flash_attention_l2r_pallas(q, k, v, bq=8, bkv=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


# ------------------------------------------------------- batching integration
def test_batcher_serves_quantized_attention_config():
    """ContinuousBatcher threads the plane-stacked cache through slot
    splicing unchanged (the new KVCache leaves ride the same tree paths)."""
    from repro.serve.batching import ContinuousBatcher, Request
    cfg = dataclasses.replace(get_smoke("smollm-135m"),
                              attn_l2r=QuantConfig())
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(17)
    prompt = rng.integers(0, cfg.vocab, (6,)).astype(np.int32)
    ref = np.asarray(greedy_generate(cfg, params, jnp.asarray(prompt[None]),
                                     steps=4, max_len=32))[0].tolist()
    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32)
    req = Request(uid=0, prompt=prompt, max_new_tokens=4)
    eng.submit(req)
    eng.run(max_steps=100)
    assert req.done and req.output[:4] == ref


# ------------------------------------------------------ roofline accounting
def test_attn_decode_bytes_accounting():
    """The analytical bytes-per-decode-step model: re-extraction moves
    the same HBM bytes as the float path (the waste is per-step
    compute), the int8 plane cache trades a widened K read for dropping
    the float K read, and a truncated walk touches only the union of
    its sliding level windows."""
    from repro.launch.roofline import HBM_BW, attn_decode_step_bytes
    b, length, kvh, dh = 4, 512, 4, 64
    acct = attn_decode_step_bytes(b, length, kvh, dh, n_bits=8,
                                  log2_radix=2, kv_dtype_bytes=2)
    m = acct["modes"]
    slots = b * length * kvh
    assert m["float"]["total_bytes"] == 2 * slots * dh * 2
    assert m["quant_reextract"]["total_bytes"] == m["float"]["total_bytes"]
    # 8-bit radix-4 -> D=4 planes, 2D-1=7 int8 blocks + f32 scale
    assert m["plane_cache"]["k_bytes"] == slots * 7 * dh
    assert m["plane_cache"]["scale_bytes"] == slots * 4
    # full-depth walk touches every block
    assert acct["plane_blocks_touched"] == 7
    assert (m["plane_cache_truncated"]["total_bytes"]
            == m["plane_cache"]["total_bytes"])
    # touched blocks = min(D + levels - 1, 2D - 1): levels=2, D=4 -> 5
    trunc = attn_decode_step_bytes(b, length, kvh, dh, n_bits=8,
                                   log2_radix=2, kv_dtype_bytes=2, levels=2)
    assert trunc["plane_blocks_touched"] == 5
    assert (trunc["modes"]["plane_cache_truncated"]["k_bytes"]
            == slots * 5 * dh)
    assert trunc["truncated_vs_plane_cache"] < 1.0
    # memory_s is bytes over the chip HBM constant
    assert m["float"]["memory_s"] == pytest.approx(
        m["float"]["total_bytes"] / HBM_BW)
