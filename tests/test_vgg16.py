"""VGG-16 with the L2R conv path (the paper's evaluation network)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import QuantConfig
from repro.models.cnn import vgg16_build, vgg16_apply
from repro.models.common import count_params, materialize


@pytest.fixture(scope="module")
def setup():
    params = materialize(vgg16_build(n_classes=10), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.standard_normal((2, 32, 32, 3)).astype(np.float32))
    return params, img


def test_param_count_matches_vgg16():
    n = count_params(vgg16_build(n_classes=1000))
    # VGG-16: 138.36M params
    assert abs(n - 138.36e6) / 138.36e6 < 0.01, n


def test_float_forward(setup):
    params, img = setup
    logits = vgg16_apply(params, img)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_l2r_path_close_to_float(setup):
    params, img = setup
    lf = np.asarray(vgg16_apply(params, img))
    lq = np.asarray(vgg16_apply(params, img, l2r=QuantConfig()))
    rel = np.abs(lq - lf).max() / (np.abs(lf).max() + 1e-9)
    assert rel < 0.25, rel  # int8 noise through 16 layers


def test_l2r_progressive_monotone(setup):
    params, img = setup
    exact = np.asarray(vgg16_apply(params, img, l2r=QuantConfig()))
    errs = []
    for lv in (3, 5, 7):
        out = np.asarray(vgg16_apply(params, img, l2r=QuantConfig(), levels=lv))
        errs.append(np.abs(out - exact).max())
    assert errs[-1] == 0  # 7 levels == full stream for radix-4 int8
    assert errs[0] >= errs[1] >= errs[2]


def test_l2r_radix16_exact_match(setup):
    """Radix choice must not change the exact result (same integer math)."""
    params, img = setup
    r4 = np.asarray(vgg16_apply(params, img, l2r=QuantConfig(log2_radix=2)))
    r16 = np.asarray(vgg16_apply(params, img, l2r=QuantConfig(log2_radix=4)))
    np.testing.assert_allclose(r4, r16, atol=1e-4)
