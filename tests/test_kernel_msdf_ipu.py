"""PE-array CIPU Pallas kernel vs the scalar golden model + integer SOP."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.msdf_ipu import cipu_array_pallas, cipu_array_ref, int_sop_ref


@pytest.mark.parametrize("m,k,n_bits", [(64, 72, 8), (100, 9, 8), (256, 16, 6),
                                        (8, 72, 8)])
def test_pe_array_exact(m, k, n_bits):
    rng = np.random.default_rng(m + k)
    hi = 1 << n_bits
    a = jnp.asarray(rng.integers(0, hi, (m, k)), jnp.int32)
    b = jnp.asarray(rng.integers(0, hi, (m, k)), jnp.int32)
    out = cipu_array_pallas(a, b, n_bits, bm=64)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(int_sop_ref(a, b)))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(cipu_array_ref(a, b, n_bits)))
