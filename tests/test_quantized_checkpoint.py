"""L2R-quantized checkpoints: size halving + bounded round-trip error +
direct serving from the quantized pytree."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.quantized import (load_quantized, quantized_nbytes,
                                        save_quantized)
from repro.configs import get_smoke
from repro.models.common import materialize, quantize_params
from repro.models.transformer import lm_build, lm_forward


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke("smollm-135m")
    desc = lm_build(cfg)
    params = materialize(desc, jax.random.PRNGKey(0))
    return cfg, desc, params


def test_quantized_checkpoint_smaller(tmp_path, model):
    cfg, desc, params = model
    q = save_quantized(desc, params, str(tmp_path / "q.npz"))
    full = quantized_nbytes(params)
    quant = quantized_nbytes(q)
    assert quant < 0.45 * full  # f32 -> int8 (+ scales + kept f32 leaves)


def test_quantized_roundtrip_error_bounded(tmp_path, model):
    cfg, desc, params = model
    path = str(tmp_path / "q.npz")
    save_quantized(desc, params, path)
    restored = load_quantized(desc, params, path, dequantize=True)
    from repro.models.common import _is_param, _quantizable

    flat_d = jax.tree.leaves(desc, is_leaf=_is_param)
    for d, a, b in zip(flat_d, jax.tree.leaves(params),
                       jax.tree.leaves(restored)):
        err = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))
        if _quantizable(d):
            bound = np.abs(np.asarray(a)).max() / 127.0 * 0.5 + 1e-6
            assert err.max() <= bound * 1.01, d.shape
        else:
            assert err.max() == 0  # norms/embeds stored exactly


def test_serve_directly_from_quantized(tmp_path, model):
    """The restored {"q","scale"} pytree feeds dense() with no dequant
    pass — the L2R serving path end to end through a checkpoint."""
    cfg, desc, params = model
    path = str(tmp_path / "q.npz")
    save_quantized(desc, params, path)
    qparams = load_quantized(desc, params, path, dequantize=False)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    h_f, _, _ = lm_forward(cfg, params, tokens=toks, mode="train")
    h_q, _, _ = lm_forward(cfg, qparams, tokens=toks, mode="train")
    rel = (np.abs(np.asarray(h_f, np.float32) - np.asarray(h_q, np.float32)).max()
           / (np.abs(np.asarray(h_f, np.float32)).max() + 1e-9))
    assert rel < 0.35, rel  # W8A8 noise through 6 layers


# ------------------------------------------------ prepared serving trees
@pytest.fixture(scope="module")
def prepared_model():
    import dataclasses

    from repro.core.quant import QuantConfig

    cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
    desc = lm_build(cfg)
    params = materialize(desc, jax.random.PRNGKey(0))
    return cfg, desc, params


def test_prepared_roundtrip_bit_exact(tmp_path, prepared_model):
    """save_prepared/load_prepared round-trips the FULL serving tree —
    int8 payloads, scales, pre-stacked PlaneOperands, and the padded
    streaming head cache — bit-exactly, leaf for leaf."""
    from repro.checkpoint.quantized import load_prepared, save_prepared
    from repro.core.quant import QuantizedWeights
    from repro.serve.engine import prepare_params

    cfg, desc, params = prepared_model
    prepared = prepare_params(cfg, params, desc)
    path = str(tmp_path / "prep.npz")
    save_prepared(prepared, path)
    restored = load_prepared(cfg, params, path, desc=desc)

    la = jax.tree_util.tree_flatten_with_path(prepared)[0]
    lb = jax.tree_util.tree_flatten_with_path(restored)[0]
    assert len(la) == len(lb)
    for (ka, a), (kb, b) in zip(la, lb):
        assert jax.tree_util.keystr(ka) == jax.tree_util.keystr(kb)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the planes caches survived: the head record restores with its
    # window-padded stack present (gateway cold start re-extracts nothing)
    assert isinstance(restored["head_q"], QuantizedWeights)
    assert restored["head_q"].planes is not None
    np.testing.assert_array_equal(
        np.asarray(prepared["head_q"].planes.stack),
        np.asarray(restored["head_q"].planes.stack))


def test_prepared_checkpoint_serves_identically(tmp_path, prepared_model):
    """Serving from the restored prepared tree is bit-identical to
    serving from the freshly prepared one — the checkpoint IS the
    cold-start path."""
    from repro.checkpoint.quantized import load_prepared, save_prepared
    from repro.serve import ContinuousBatcher, Request
    from repro.serve.engine import prepare_params

    cfg, desc, params = prepared_model
    prepared = prepare_params(cfg, params, desc)
    path = str(tmp_path / "prep.npz")
    save_prepared(prepared, path)
    restored = load_prepared(cfg, params, path, desc=desc)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 9)]

    def serve(tree):
        eng = ContinuousBatcher(cfg, tree, n_slots=2, max_len=24,
                                progressive=True, early_exit=True)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=200)
        return [(r.output, r.exit_levels) for r in reqs]

    assert serve(prepared) == serve(restored)


def test_quantize_params_matches_quantize_desc_structure(model):
    cfg, desc, params = model
    from repro.models.common import quantize_desc

    qdesc = quantize_desc(desc)
    qparams = quantize_params(desc, params)
    s1 = jax.tree_util.tree_structure(
        jax.tree.map(lambda _: 0, qdesc,
                     is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes")))
    s2 = jax.tree_util.tree_structure(jax.tree.map(lambda _: 0, qparams))
    assert s1 == s2


# ---------------------------------------------------------------- KV caches
# Round-trips of state trees containing the None-defaulted
# `KVCache.k_planes`/`k_scale` fields: None fields are EMPTY pytree
# nodes (no leaves, no .npz keys), so a cache saved without the plane
# stack — which is byte-identical to what the pre-plane-stack 3-field
# KVCache wrote — loads straight into the new 5-field structure, and a
# plane-stacked cache restores its int8 stack and per-slot scales
# bit-exact.

def _filled_kv_cache(quant=None, dtype=jnp.float32):
    from repro.core.quant import QuantConfig  # noqa: F401 (doc pointer)
    from repro.models.attention import init_kv_cache, update_kv_cache

    rng = np.random.default_rng(5)
    b, L, kv, dh, s = 2, 8, 2, 4, 3
    cache = init_kv_cache(b, L, kv, dh, dtype=dtype, quant=quant)
    k_new = jnp.asarray(rng.normal(size=(b, s, kv, dh)), dtype)
    v_new = jnp.asarray(rng.normal(size=(b, s, kv, dh)), dtype)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return update_kv_cache(cache, k_new, v_new, pos, quant=quant)


def _assert_trees_bit_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_kv_cache_none_planes_roundtrip(tmp_path):
    from repro.checkpoint.manager import load_pytree, save_pytree
    from repro.models.attention import init_kv_cache

    cache = _filled_kv_cache(quant=None)
    assert cache.k_planes is None and cache.k_scale is None
    path = str(tmp_path / "kv.npz")
    save_pytree(cache, path)
    template = jax.eval_shape(
        lambda: init_kv_cache(2, 8, 2, 4, dtype=jnp.float32))
    restored = load_pytree(template, path)
    assert restored.k_planes is None and restored.k_scale is None
    _assert_trees_bit_equal(cache, restored)


def test_kv_cache_old_checkpoint_loads_into_new_structure(tmp_path):
    """A pre-plane-stack checkpoint (written when KVCache had only
    k/v/positions) carries exactly the keys of a None-field save — so
    the emulated old .npz loads into the new structure unchanged."""
    from repro.checkpoint.manager import load_pytree
    from repro.models.attention import init_kv_cache

    cache = _filled_kv_cache(quant=None)
    path = str(tmp_path / "old_kv.npz")
    # the old 3-field writer: attr-keyed leaves, no plane entries
    np.savez(path, **{".k": np.asarray(cache.k),
                      ".v": np.asarray(cache.v),
                      ".positions": np.asarray(cache.positions)})
    template = jax.eval_shape(
        lambda: init_kv_cache(2, 8, 2, 4, dtype=jnp.float32))
    restored = load_pytree(template, path)
    assert restored.k_planes is None and restored.k_scale is None
    _assert_trees_bit_equal(cache, restored)


def test_kv_cache_plane_stack_roundtrip_bit_exact(tmp_path):
    from repro.checkpoint.manager import load_pytree, save_pytree
    from repro.core.quant import QuantConfig
    from repro.models.attention import init_kv_cache

    quant = QuantConfig()
    cache = _filled_kv_cache(quant=quant)
    assert cache.k_planes is not None and cache.k_planes.dtype == jnp.int8
    path = str(tmp_path / "kvq.npz")
    save_pytree(cache, path)
    template = jax.eval_shape(
        lambda: init_kv_cache(2, 8, 2, 4, dtype=jnp.float32, quant=quant))
    restored = load_pytree(template, path)
    _assert_trees_bit_equal(cache, restored)
