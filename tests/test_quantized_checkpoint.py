"""L2R-quantized checkpoints: size halving + bounded round-trip error +
direct serving from the quantized pytree."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.quantized import (load_quantized, quantized_nbytes,
                                        save_quantized)
from repro.configs import get_smoke
from repro.models.common import materialize, quantize_params
from repro.models.transformer import lm_build, lm_forward


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke("smollm-135m")
    desc = lm_build(cfg)
    params = materialize(desc, jax.random.PRNGKey(0))
    return cfg, desc, params


def test_quantized_checkpoint_smaller(tmp_path, model):
    cfg, desc, params = model
    q = save_quantized(desc, params, str(tmp_path / "q.npz"))
    full = quantized_nbytes(params)
    quant = quantized_nbytes(q)
    assert quant < 0.45 * full  # f32 -> int8 (+ scales + kept f32 leaves)


def test_quantized_roundtrip_error_bounded(tmp_path, model):
    cfg, desc, params = model
    path = str(tmp_path / "q.npz")
    save_quantized(desc, params, path)
    restored = load_quantized(desc, params, path, dequantize=True)
    from repro.models.common import Param, _is_param, _quantizable

    flat_d = jax.tree.leaves(desc, is_leaf=_is_param)
    for d, a, b in zip(flat_d, jax.tree.leaves(params),
                       jax.tree.leaves(restored)):
        err = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))
        if _quantizable(d):
            bound = np.abs(np.asarray(a)).max() / 127.0 * 0.5 + 1e-6
            assert err.max() <= bound * 1.01, d.shape
        else:
            assert err.max() == 0  # norms/embeds stored exactly


def test_serve_directly_from_quantized(tmp_path, model):
    """The restored {"q","scale"} pytree feeds dense() with no dequant
    pass — the L2R serving path end to end through a checkpoint."""
    cfg, desc, params = model
    path = str(tmp_path / "q.npz")
    save_quantized(desc, params, path)
    qparams = load_quantized(desc, params, path, dequantize=False)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    h_f, _, _ = lm_forward(cfg, params, tokens=toks, mode="train")
    h_q, _, _ = lm_forward(cfg, qparams, tokens=toks, mode="train")
    rel = (np.abs(np.asarray(h_f, np.float32) - np.asarray(h_q, np.float32)).max()
           / (np.abs(np.asarray(h_f, np.float32)).max() + 1e-9))
    assert rel < 0.35, rel  # W8A8 noise through 6 layers


# ------------------------------------------------ prepared serving trees
@pytest.fixture(scope="module")
def prepared_model():
    import dataclasses

    from repro.core.quant import QuantConfig

    cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
    desc = lm_build(cfg)
    params = materialize(desc, jax.random.PRNGKey(0))
    return cfg, desc, params


def test_prepared_roundtrip_bit_exact(tmp_path, prepared_model):
    """save_prepared/load_prepared round-trips the FULL serving tree —
    int8 payloads, scales, pre-stacked PlaneOperands, and the padded
    streaming head cache — bit-exactly, leaf for leaf."""
    from repro.checkpoint.quantized import load_prepared, save_prepared
    from repro.core.quant import QuantizedWeights
    from repro.serve.engine import prepare_params

    cfg, desc, params = prepared_model
    prepared = prepare_params(cfg, params, desc)
    path = str(tmp_path / "prep.npz")
    save_prepared(prepared, path)
    restored = load_prepared(cfg, params, path, desc=desc)

    la = jax.tree_util.tree_flatten_with_path(prepared)[0]
    lb = jax.tree_util.tree_flatten_with_path(restored)[0]
    assert len(la) == len(lb)
    for (ka, a), (kb, b) in zip(la, lb):
        assert jax.tree_util.keystr(ka) == jax.tree_util.keystr(kb)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the planes caches survived: the head record restores with its
    # window-padded stack present (gateway cold start re-extracts nothing)
    assert isinstance(restored["head_q"], QuantizedWeights)
    assert restored["head_q"].planes is not None
    np.testing.assert_array_equal(
        np.asarray(prepared["head_q"].planes.stack),
        np.asarray(restored["head_q"].planes.stack))


def test_prepared_checkpoint_serves_identically(tmp_path, prepared_model):
    """Serving from the restored prepared tree is bit-identical to
    serving from the freshly prepared one — the checkpoint IS the
    cold-start path."""
    from repro.checkpoint.quantized import load_prepared, save_prepared
    from repro.serve import ContinuousBatcher, Request
    from repro.serve.engine import prepare_params

    cfg, desc, params = prepared_model
    prepared = prepare_params(cfg, params, desc)
    path = str(tmp_path / "prep.npz")
    save_prepared(prepared, path)
    restored = load_prepared(cfg, params, path, desc=desc)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 9)]

    def serve(tree):
        eng = ContinuousBatcher(cfg, tree, n_slots=2, max_len=24,
                                progressive=True, early_exit=True)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=200)
        return [(r.output, r.exit_levels) for r in reqs]

    assert serve(prepared) == serve(restored)


def test_quantize_params_matches_quantize_desc_structure(model):
    cfg, desc, params = model
    from repro.models.common import quantize_desc

    qdesc = quantize_desc(desc)
    qparams = quantize_params(desc, params)
    s1 = jax.tree_util.tree_structure(
        jax.tree.map(lambda _: 0, qdesc,
                     is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes")))
    s2 = jax.tree_util.tree_structure(jax.tree.map(lambda _: 0, qparams))
    assert s1 == s2
