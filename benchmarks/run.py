"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * table1_*    — synthesis model vs paper Table I (area µm² / power mW /
                  critical path ns);
  * table2_*    — Table II columns (peak GOPS, TOPS/W, GOPS/mm²) + the
                  headline multiples vs [4] Cheng and [5] Eyeriss;
  * vgg16_*     — per-layer + total Cycle_P walk (execution-cycles table)
                  for L2R vs the Loom-pattern baseline;
  * kernel_*    — wall-time microbenches of the digit-plane GEMM paths on
                  this host (CPU; interpret-mode Pallas excluded from
                  timing claims, jnp reference path timed);
  * kernel_stacked_* — pair-loop vs level-stacked schedule (the PR's
                  restructured execution order: 2D-1 fused level matmuls
                  instead of D² pair passes), jnp production path timed,
                  pallas-interpret validated; rows also land in
                  BENCH_l2r_gemm.json for the cross-PR perf trajectory;
  * kernel_prestacked_* — pre-stacked plane-operand amortization: GEMM
                  with the load-time RHS plane-stack cache vs inline
                  per-call extraction, the fused conv layer with the
                  cached weight stack, and the prestacked Pallas conv
                  path (correctness rows in interpret mode);
  * kernel_tilesweep_* — (bm, bk, bn) tile sweep of the stacked Pallas
                  kernel: timed on TPU hosts, correctness-validated in
                  interpret mode elsewhere (the real-TPU tuning entry);
  * ipu_*       — cycle-accurate CIPU simulator throughput;
  * online_*    — progressive-precision early-exit statistics;
  * progressive_* — the streaming early-exit suite: VGG-16 logit-head
                  exit levels (prototype-calibrated head — the decisive-
                  margin regime of a trained classifier) + wall-clock of
                  the stacked GEMM truncated at the mean exit level vs
                  the full stream; rows land in BENCH_progressive.json;
  * progressive_sharded_* — the multi-device consensus head walk
                  (core/progressive.py sharded streaming_argmax) vs the
                  single-device stream on a host-platform virtual-device
                  mesh (subprocess: the device-count flag must precede
                  jax init).  Decisions/exit levels verified bit-exact
                  before timing; on one shared CPU the "scaling" number
                  measures partitioning overhead, not parallel speedup —
                  the real-accelerator row is a deployment follow-up.
  * attention_* — digit-serial attention decode modes on one KV cache:
                  float oracle vs quantized QK^T re-extracting K planes
                  per step vs the incrementally plane-stacked cache vs
                  margin-bounded early exit, parity asserted bit-exact
                  before timing (plane cache == re-extraction; early
                  exit == full depth at tight tolerance); plus the
                  chunked quantized prefill and an interpret-mode
                  correctness row for the flash-fused level-walk
                  kernel; rows land in BENCH_attention.json;
  * serving_*   — the gateway under synthetic Poisson traffic (bucketed
                  AOT prefill, donated decode state, async emit):
                  tokens/s + p50/p99 TTFT and per-token latency, early
                  exit on vs off, output asserted bit-identical to the
                  plain batcher; rows land in BENCH_serving.json.

    PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

# --check smoke mode (CI): 1 repetition, no warmup — exercises every
# bench path without pretending the numbers are a timing signal.
CHECK_MODE = False


def _timeit(fn, n=5, warmup=2):
    if CHECK_MODE:
        n, warmup = 1, 0
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def _best_pair(fa, fb, n, rounds=3):
    """Interleaved min-of-rounds timing for every A-vs-B comparison: the
    effects measured here are 10-30% of a GEMM on a shared CPU host,
    where one-round means drift by that much between the two
    measurements."""
    if CHECK_MODE:
        rounds = 1
    best_a = best_b = float("inf")
    for _ in range(rounds):
        best_a = min(best_a, _timeit(fa, n=n, warmup=0))
        best_b = min(best_b, _timeit(fb, n=n, warmup=0))
    return best_a, best_b


def emit(name: str, us: float | str, derived):
    print(f"{name},{us if isinstance(us, str) else f'{us:.1f}'},{derived}")


def table1():
    from repro.core import hw_model
    t0 = time.perf_counter()
    t1 = hw_model.table1()
    us = (time.perf_counter() - t0) * 1e6
    for design in ("baseline", "l2r_cipu"):
        p = hw_model.PAPER_TABLE1[design]
        m = t1[design]
        emit(f"table1_{design}_area_um2", us,
             f"model={m['area_um2']:.2f} paper={p['area_um2']}")
        emit(f"table1_{design}_power_mw", us,
             f"model={m['power_mw']:.2f} paper={p['power_mw']}")
        emit(f"table1_{design}_latency_ns", us,
             f"model={m['latency_ns']:.3f} paper={p['latency_ns']} "
             f"delta={(m['latency_ns']-p['latency_ns'])/p['latency_ns']*100:+.1f}%")


def table2():
    from repro.core import hw_model
    t2 = hw_model.table2()
    p = hw_model.PAPER_TABLE2
    for design in ("baseline", "l2r_cipu"):
        m = t2[design]
        emit(f"table2_{design}_peak_gops", 0.0,
             f"model={m['gops']:.2f} paper={p[design]['gops']}")
        emit(f"table2_{design}_tops_w", 0.0,
             f"model={m['tops_w']:.3f} paper={p[design]['tops_w']}")
        emit(f"table2_{design}_gops_mm2", 0.0,
             f"model={m['gops_mm2']:.2f} paper={p[design]['gops_mm2']}")
    emit("table2_perf_vs_cheng2024", 0.0,
         f"model={t2['l2r_cipu']['gops']/p['cheng2024']['gops']:.2f}x paper=6.22x")
    emit("table2_energy_vs_cheng2024", 0.0,
         f"model={t2['l2r_cipu']['tops_w']/p['cheng2024']['tops_w']:.1f}x paper=15x")
    emit("table2_perf_vs_eyeriss", 0.0,
         f"model={t2['l2r_cipu']['gops']/p['eyeriss']['gops']:.2f}x paper=1.06x")
    emit("table2_area_vs_eyeriss", 0.0,
         f"model={t2['l2r_cipu']['gops_mm2']/p['eyeriss']['gops_mm2']:.2f}x paper=53.45x")


def vgg16_cycles():
    from repro.core.cycle_model import (VGG16_CONV_LAYERS, layer_cycles,
                                        network_cycles, AcceleratorConfig)
    cfg = AcceleratorConfig()
    for layer in VGG16_CONV_LAYERS:
        c_l2r = layer_cycles(layer, cfg, l2r=True)
        c_base = layer_cycles(layer, cfg, l2r=False)
        emit(f"vgg16_cycles_{layer.name}", 0.0,
             f"l2r={c_l2r} baseline={c_base} speedup={c_base/c_l2r:.3f}x")
    tot_l, tot_b = network_cycles(l2r=True), network_cycles(l2r=False)
    emit("vgg16_cycles_total", 0.0,
         f"l2r={tot_l} baseline={tot_b} speedup={tot_b/tot_l:.3f}x paper=3.40x")


def kernel_bench():
    from repro.kernels.l2r_gemm import l2r_gemm_ref, int_gemm_ref
    rng = np.random.default_rng(0)
    for (m, k, n) in [(256, 512, 256), (512, 1024, 512)]:
        a = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
        b = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
        f_ref = jax.jit(lambda x, y: int_gemm_ref(x, y))
        f_l2r = jax.jit(lambda x, y: l2r_gemm_ref(x, y))
        f_l2r3 = jax.jit(lambda x, y: l2r_gemm_ref(x, y, levels=3))
        us_ref = _timeit(lambda: jax.block_until_ready(f_ref(a, b)))
        us_l2r = _timeit(lambda: jax.block_until_ready(f_l2r(a, b)))
        us_l2r3 = _timeit(lambda: jax.block_until_ready(f_l2r3(a, b)))
        gflop = 2 * m * k * n / 1e9
        emit(f"kernel_int_gemm_{m}x{k}x{n}", us_ref,
             f"gflops={gflop/(us_ref/1e6):.2f}")
        emit(f"kernel_l2r_gemm_full_{m}x{k}x{n}", us_l2r,
             f"planes=16pairs exact=True")
        emit(f"kernel_l2r_gemm_lv3_{m}x{k}x{n}", us_l2r3,
             f"planes=6pairs progressive=True")


def kernel_stacked_bench(json_path: str | None = None):
    """Pair-loop vs level-stacked schedule + backend dispatch regression.

    Emits kernel_stacked_* CSV rows and (optionally) a machine-readable
    BENCH_l2r_gemm.json so future PRs can diff the perf trajectory.
    """
    import json

    from repro.kernels.l2r_gemm import (l2r_gemm, l2r_gemm_ref,
                                        l2r_gemm_ref_stacked)

    rng = np.random.default_rng(0)
    records = []
    for (m, k, n) in [(256, 512, 256), (512, 1024, 512)]:
        a = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
        b = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
        for levels, tag in [(None, "full"), (3, "lv3")]:
            f_pair = jax.jit(lambda x, y, lv=levels: l2r_gemm_ref(x, y, levels=lv))
            f_stack = jax.jit(
                lambda x, y, lv=levels: l2r_gemm_ref_stacked(x, y, levels=lv))
            us_pair = _timeit(lambda: jax.block_until_ready(f_pair(a, b)))
            us_stack = _timeit(lambda: jax.block_until_ready(f_stack(a, b)))
            exact = bool(
                (np.asarray(f_pair(a, b)) == np.asarray(f_stack(a, b))).all())
            emit(f"kernel_stacked_jnp_{tag}_{m}x{k}x{n}", us_stack,
                 f"pair_us={us_pair:.1f} speedup={us_pair/us_stack:.2f}x "
                 f"bit_exact={exact}")
            records.append({
                "name": f"jnp_{tag}_{m}x{k}x{n}", "m": m, "k": k, "n": n,
                "levels": levels, "backend": "jnp",
                "pair_us": us_pair, "stacked_us": us_stack,
                "speedup": us_pair / us_stack, "bit_exact": exact,
            })
    # Pallas interpret mode: correctness-only (CPU interpretation is not a
    # timing signal) — one small shape, both schedules vs the jnp oracle.
    m, k, n = 128, 256, 128
    a = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
    ref = np.asarray(l2r_gemm_ref(a, b))
    for sched in ("pairs", "stacked"):
        out = np.asarray(l2r_gemm(a, b, schedule=sched,
                                  backend="pallas-interpret"))
        exact = bool((out == ref).all())
        emit(f"kernel_stacked_pallas_interpret_{sched}_{m}x{k}x{n}",
             "untimed", f"bit_exact={exact}")
        records.append({
            "name": f"pallas_interpret_{sched}_{m}x{k}x{n}",
            "m": m, "k": k, "n": n, "levels": None,
            "backend": "pallas-interpret", "schedule": sched,
            "bit_exact": exact,
        })
    kernel_prestacked_bench(records)
    kernel_tile_sweep(records)
    if json_path:
        payload = {
            "bench": "l2r_gemm_level_stacking",
            "host_backend": jax.default_backend(),
            "timing_note": "jnp path timed on this host; pallas-interpret "
                           "rows are correctness-only",
            "rows": records,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        emit("kernel_stacked_json", 0.0, f"wrote={json_path}")


def kernel_prestacked_bench(records: list):
    """Pre-stacked plane-operand amortization -> kernel_prestacked_* rows.

    What is measurable on this host: the jnp paths with the load-time
    weight plane-stack cache vs inline per-call extraction (the cache
    removes D mask+shift passes over the weight from every call — the
    decode/conv steady state), timed; the prestacked Pallas conv path
    (activation planes hoisted once per feature map, weight stack cached
    — ONE extraction per call instead of one per tap) is
    correctness-validated in interpret mode, its wall-clock being a
    real-TPU follow-up.
    """
    from repro.core.quant import PlaneOperands, QuantConfig, quantize_weights
    from repro.kernels.l2r_gemm import l2r_conv2d, l2r_gemm

    rng = np.random.default_rng(7)
    # GEMM: cached RHS plane stack vs per-call extraction (jnp stacked)
    for (m, k, n) in [(256, 2048, 512)]:
        a = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
        b = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
        pb = PlaneOperands.prepare_rhs(b)
        f_raw = jax.jit(lambda x, y: l2r_gemm(x, y))
        f_pre = jax.jit(lambda x, y: l2r_gemm(x, y))
        jax.block_until_ready(f_raw(a, b))
        jax.block_until_ready(f_pre(a, pb))
        exact = bool((np.asarray(f_raw(a, b)) == np.asarray(f_pre(a, pb))).all())
        us_raw, us_pre = _best_pair(
            lambda: jax.block_until_ready(f_raw(a, b)),
            lambda: jax.block_until_ready(f_pre(a, pb)), n=10)
        emit(f"kernel_prestacked_gemm_rhs_cache_{m}x{k}x{n}", us_pre,
             f"inline_us={us_raw:.1f} speedup={us_raw/us_pre:.2f}x "
             f"bit_exact={exact}")
        records.append({
            "name": f"prestacked_gemm_rhs_cache_{m}x{k}x{n}",
            "m": m, "k": k, "n": n, "backend": "jnp",
            "inline_us": us_raw, "prestacked_us": us_pre,
            "speedup": us_raw / us_pre, "bit_exact": exact,
        })
    # conv layer: a VGG-shaped 3x3 with and without the cached weight
    # stack (jnp: activation hoist is shared; the delta is the per-call
    # weight extraction the cache removes)
    cfg = QuantConfig()
    x = jnp.asarray(rng.standard_normal((4, 32, 32, 64)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((3, 3, 64, 64)).astype(np.float32))
    plain = quantize_weights(w, cfg)
    pre = quantize_weights(w, cfg, prestack=True, plane_axis=-2)
    f_plain = jax.jit(lambda xx: l2r_conv2d(xx, None, cfg=cfg, w_q=plain,
                                            backend="jnp"))
    f_pre = jax.jit(lambda xx: l2r_conv2d(xx, None, cfg=cfg, w_q=pre,
                                          backend="jnp"))
    jax.block_until_ready(f_plain(x))
    jax.block_until_ready(f_pre(x))
    exact = bool((np.asarray(f_plain(x)) == np.asarray(f_pre(x))).all())
    us_plain, us_pre = _best_pair(
        lambda: jax.block_until_ready(f_plain(x)),
        lambda: jax.block_until_ready(f_pre(x)), n=5)
    emit("kernel_prestacked_conv_w_cache_4x32x32x64", us_pre,
         f"inline_us={us_plain:.1f} speedup={us_plain/us_pre:.2f}x "
         f"bit_exact={exact}")
    records.append({
        "name": "prestacked_conv_w_cache_4x32x32x64", "backend": "jnp",
        "inline_us": us_plain, "prestacked_us": us_pre,
        "speedup": us_plain / us_pre, "bit_exact": exact,
    })
    # prestacked Pallas conv path: correctness in interpret mode (the
    # per-feature-map hoist + cached weight stack reach the pre-stacked
    # kernel entries; timing is a real-TPU follow-up)
    xs_ = jnp.asarray(rng.standard_normal((1, 8, 8, 5)).astype(np.float32))
    ws_ = jnp.asarray(rng.standard_normal((3, 3, 5, 6)).astype(np.float32))
    pre_s = quantize_weights(ws_, cfg, prestack=True, plane_axis=-2)
    o_ref = np.asarray(l2r_conv2d(xs_, None, cfg=cfg,
                                  w_q=quantize_weights(ws_, cfg),
                                  backend="jnp"))
    o_pal = np.asarray(l2r_conv2d(xs_, None, cfg=cfg, w_q=pre_s,
                                  backend="pallas-interpret"))
    exact = bool((o_ref == o_pal).all())
    emit("kernel_prestacked_conv_pallas_interpret_1x8x8x5", "untimed",
         f"bit_exact={exact}")
    records.append({
        "name": "prestacked_conv_pallas_interpret_1x8x8x5",
        "backend": "pallas-interpret", "bit_exact": exact,
    })


def kernel_tile_sweep(records: list):
    """(bm, bk, bn) tile sweep of the stacked Pallas kernel.

    On a TPU host every configuration is compiled and timed (the tuning
    signal the ROADMAP follow-up needs); elsewhere each tile shape is
    validated bit-exact in interpret mode so the sweep machinery itself
    is exercised per CI run.  CHECK_MODE trims the sweep to two configs.
    """
    from repro.kernels.l2r_gemm import l2r_gemm, l2r_gemm_ref

    on_tpu = jax.default_backend() == "tpu"
    tiles = [(128, 128, 128), (128, 256, 128), (128, 512, 128),
             (256, 256, 128), (128, 256, 256)]
    if CHECK_MODE:
        tiles = tiles[:2]
    m, k, n = (1024, 2048, 1024) if on_tpu else (256, 512, 128)
    rng = np.random.default_rng(8)
    a = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
    ref = np.asarray(l2r_gemm_ref(a, b))
    backend = "pallas-tpu" if on_tpu else "pallas-interpret"
    for (bm, bk, bn) in tiles:
        fn = jax.jit(lambda x, y, t=(bm, bk, bn): l2r_gemm(
            x, y, bm=t[0], bk=t[1], bn=t[2], backend=backend))
        out = np.asarray(fn(a, b))
        exact = bool((out == ref).all())
        row = {"name": f"tilesweep_{bm}x{bk}x{bn}_{m}x{k}x{n}",
               "m": m, "k": k, "n": n, "bm": bm, "bk": bk, "bn": bn,
               "backend": backend, "bit_exact": exact}
        if on_tpu:
            us = _timeit(lambda: jax.block_until_ready(fn(a, b)), n=10)
            row["us"] = us
            emit(f"kernel_tilesweep_{bm}x{bk}x{bn}_{m}x{k}x{n}", us,
                 f"bit_exact={exact}")
        else:
            emit(f"kernel_tilesweep_{bm}x{bk}x{bn}_{m}x{k}x{n}", "untimed",
                 f"bit_exact={exact} (interpret: correctness only)")
        records.append(row)


def ipu_bench():
    from repro.core.ipu import simulate_cipu
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(0, 256, (64, 72)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 256, (64, 72)), jnp.int32)
    f = jax.jit(lambda x, y: simulate_cipu(x, y, 8).final)
    us = _timeit(lambda: jax.block_until_ready(f(a, b)))
    emit("ipu_cycle_accurate_sim_64sops", us,
         f"cycles_per_sop=64 sops_per_s={64/(us/1e6):.0f}")


def online_stats():
    from repro.core.progressive import (earliest_decision_level,
                                        progressive_matmul)
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(-128, 128, (256, 64), dtype=np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (64, 32), dtype=np.int8))
    res = progressive_matmul(a, b)
    lv = np.asarray(earliest_decision_level(res))
    emit("online_early_exit_mean_level", 0.0,
         f"mean={lv.mean():.2f} of {res.partial.shape[0]-1} "
         f"(argmax decided after {100*(lv.mean()+1)/res.partial.shape[0]:.0f}% of stream)")


def _load_calibrate_levels():
    """Import tools/calibrate_levels.py by path (tools/ is not a
    package: the calibration controller is an offline CLI that the
    bench reuses for fitting and the frontier-row schema)."""
    import importlib.util

    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "..", "tools", "calibrate_levels.py")
    spec = importlib.util.spec_from_file_location("calibrate_levels", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def precision_policy_bench(rows: list):
    """Per-request precision classes on the decisive prototype head:
    the accuracy-vs-levels-vs-latency frontier of the LevelPolicy
    operating points — ``exact`` (full-depth scan), every ``budget(L)``
    clamp, the ``bounded`` margin walk, and the budget CALIBRATED from
    the bounded walk's observed exit histogram
    (tools/calibrate_levels.py, coverage 0.99).  Appends one
    ``precision_policy_frontier`` record (one frontier row per
    operating point) to ``rows`` for BENCH_progressive.json.
    """
    from repro.core.policy import LevelPolicy
    from repro.core.progressive import streaming_argmax
    from repro.core.quant import QuantConfig
    from repro.models.protohead import prototype_head

    cal = _load_calibrate_levels()
    cfg = QuantConfig()
    n_levels = 2 * cfg.planes - 1
    k, classes, m = (512, 32, 64) if CHECK_MODE else (2048, 64, 256)
    xq, xs, w_q, _ = prototype_head(np.random.default_rng(44), k, classes,
                                    m, cfg=cfg)

    def run(policy, early_exit=True):
        f = jax.jit(lambda a, s: streaming_argmax(
            a, w_q.q, s, w_q.scale, cfg.n_bits, cfg.log2_radix,
            early_exit=early_exit, policy=policy)[1:])
        tok, lv = jax.tree.map(np.asarray, f(xq, xs))
        return f, tok, lv

    # exact class = the full-depth scan: the accuracy reference AND the
    # latency baseline every other operating point is timed against
    f_exact, tok_exact, lv_exact = run(LevelPolicy.exact(m),
                                       early_exit=False)
    frontier = [cal.frontier_row("exact", n_levels, n_levels, 1.0,
                                 float(lv_exact.mean()))]

    def point(label, policy, levels):
        f, tok, lv = run(policy)
        us_e, us_p = _best_pair(
            lambda: jax.block_until_ready(f_exact(xq, xs)),
            lambda: jax.block_until_ready(f(xq, xs)), n=5)
        frontier.append(cal.frontier_row(
            label, levels, n_levels, float((tok == tok_exact).mean()),
            float(lv.mean()), us=us_p, full_us=us_e))
        return lv

    for lvl in range(1, n_levels + 1):
        point(f"budget({lvl})", LevelPolicy.budget(lvl, m), lvl)
    lv_b = point("bounded(0)", LevelPolicy.bounded(m),
                 int(lv_exact.max()) + 1)
    # the bounded walk is sound (agreement 1.0 by construction); its
    # exit histogram is what serving stats() observe — fit the smallest
    # clamp covering 99% of those exits and measure the fitted point
    coverage = 0.99
    fitted = cal.fit_budget(np.bincount(lv_b, minlength=n_levels),
                            coverage=coverage)
    point(f"calibrated:budget({fitted})", LevelPolicy.budget(fitted, m),
          fitted)
    frontier[-1].update(calibrated=True, coverage=coverage,
                        fitted_from="bounded(0)")
    agree = frontier[-1]["agreement_vs_exact"]
    emit("precision_policy_frontier", frontier[-1].get("us_per_call", 0.0),
         f"points={len(frontier)} calibrated_budget={fitted}/{n_levels} "
         f"calibrated_agreement={agree:.3f} "
         f"bounded_mean_exit={float(lv_b.mean()):.2f}")
    rows.append({
        "name": "precision_policy_frontier", "n_levels": n_levels,
        "k": k, "classes": classes, "rows": m,
        "coverage": coverage, "calibrated_budget_levels": fitted,
        "frontier": frontier,
    })


def progressive_bench(json_path: str | None = None):
    """Streaming early-exit suite -> progressive_* rows + JSON record.

    The VGG-16 logit benchmark: the L2R trunk runs exactly and the fc8
    head streams most-significant-level first, each image committing its
    class at its earliest sound level.  An untrained random head has
    exchangeable logits (top-1 margins ~0), so the head is **prototype-
    calibrated** — class c's weight column is the trunk feature of a
    reference image — which reproduces the decisive-margin regime a
    trained classifier operates in.  Wall-clock saved is measured by
    timing the stacked head GEMM truncated at the mean exit level
    against the full 2D-1-level stream (identical operands).
    """
    import json

    from repro.core.quant import QuantConfig, quantize
    from repro.kernels.l2r_gemm import l2r_gemm
    from repro.models.cnn import (_vgg16_trunk, vgg16_build,
                                  vgg16_classify_progressive,
                                  vgg16_quantize_weights)
    from repro.models.common import materialize

    cfg = QuantConfig()
    n_classes = 32
    n_levels = 2 * cfg.planes - 1
    rng = np.random.default_rng(0)
    params = materialize(vgg16_build(n_classes=n_classes),
                         jax.random.PRNGKey(0))
    cache = vgg16_quantize_weights(params, cfg)
    # prototype-calibrate the head: one reference image per class, its
    # CENTERED trunk feature becomes that class's fc8 column (random-init
    # VGG features share a large all-positive common mode; centering
    # removes it so class margins are decisive, and the matching bias
    # -mu @ W makes the logit the centered-prototype similarity)
    ref = jnp.asarray(rng.standard_normal((n_classes, 32, 32, 3))
                      .astype(np.float32))
    feats, _ = _vgg16_trunk(params, ref, cfg, None, cache, None)
    f_np = np.asarray(feats, np.float32)
    mu = f_np.mean(0, keepdims=True)
    w8 = (f_np - mu).T  # (4096, n_classes)
    w8 = w8 / (np.linalg.norm(w8, axis=0, keepdims=True) + 1e-9)
    params["fc8"]["w"] = jnp.asarray(w8)
    params["fc8"]["b"] = jnp.asarray(-(mu @ w8)[0])
    cache = vgg16_quantize_weights(params, cfg)
    # queries: noisy copies of reference images
    sel = rng.integers(0, n_classes, 16)
    imgs = ref[sel] + 0.1 * jnp.asarray(
        rng.standard_normal((16, 32, 32, 3)).astype(np.float32))
    pred, lv, _ = vgg16_classify_progressive(params, imgs, cfg,
                                             weights_q=cache)
    lv = np.asarray(lv)
    acc = float((np.asarray(pred) == sel).mean())
    mean_exit = float(lv.mean())
    hist = np.bincount(lv, minlength=n_levels).tolist()
    emit("progressive_vgg16_logit_exit_level", 0.0,
         f"mean={mean_exit:.2f} of {n_levels - 1} "
         f"early_frac={float((lv < n_levels - 1).mean()):.2f} "
         f"proto_acc={acc:.2f}")

    # wall-clock saved: the stacked head GEMM at the mean exit depth vs
    # the full stream, on the real head operands (rows tiled to a
    # serving-sized batch so the timing is dominated by the GEMM, not
    # dispatch noise); _best_pair interleaving throughout
    best_pair = _best_pair

    x, _ = _vgg16_trunk(params, imgs, cfg, None, cache, None)
    xq, xs = quantize(x, cfg, axis=0)
    xqt = jnp.tile(xq, (16, 1))  # (256, 4096)
    wq = cache["fc8"].q
    trunc = int(round(mean_exit)) + 1
    f_full = jax.jit(lambda a, b: l2r_gemm(a, b, cfg.n_bits, cfg.log2_radix))
    f_trunc = jax.jit(
        lambda a, b: l2r_gemm(a, b, cfg.n_bits, cfg.log2_radix, levels=trunc))
    jax.block_until_ready(f_full(xqt, wq))  # compile untimed
    jax.block_until_ready(f_trunc(xqt, wq))
    us_full, us_trunc = best_pair(
        lambda: jax.block_until_ready(f_full(xqt, wq)),
        lambda: jax.block_until_ready(f_trunc(xqt, wq)), n=10)
    saved = 1.0 - us_trunc / us_full
    emit("progressive_vgg16_head_gemm_truncated", us_trunc,
         f"full_us={us_full:.1f} levels={trunc}/{n_levels} "
         f"wallclock_saved={saved * 100:.0f}%")

    # early-exit SCAN wall-clock: the while-loop emitter stops the level
    # loop inside one fused computation the moment every row has decided
    # — measured against the fixed-length scan on the SAME head operands
    # and decision fold (not a static truncation: the exit level is
    # discovered at runtime).  Rows are tiled (decision state is
    # per-row-identical under tiling) so the timing is GEMM-dominated.
    from repro.core.progressive import streaming_argmax

    ws = cache["fc8"].scale
    bias = params["fc8"]["b"]
    xst = jnp.tile(xs, (16, 1))
    f_scan = jax.jit(lambda a, s: streaming_argmax(
        a, wq, s, ws, cfg.n_bits, cfg.log2_radix, bias=bias)[1])
    f_while = jax.jit(lambda a, s: streaming_argmax(
        a, wq, s, ws, cfg.n_bits, cfg.log2_radix, bias=bias,
        early_exit=True)[1])
    tok_scan = np.asarray(f_scan(xqt, xst))
    tok_while = np.asarray(f_while(xqt, xst))
    assert (tok_scan == tok_while).all(), "early-exit changed a decision"
    us_scan, us_while = best_pair(
        lambda: jax.block_until_ready(f_scan(xqt, xst)),
        lambda: jax.block_until_ready(f_while(xqt, xst)), n=10)
    ee_saved = 1.0 - us_while / us_scan
    emit("progressive_vgg16_head_early_exit_scan", us_while,
         f"scan_us={us_scan:.1f} batch_exit_level={int(lv.max())}/"
         f"{n_levels - 1} wallclock_saved={ee_saved * 100:.0f}%")

    # per-image tiles exit at each image's OWN level (a batch tile exits
    # at its slowest row): the serving-shaped measurement
    tiles = [(jnp.tile(xq[i:i + 1], (128, 1)),
              jnp.tile(xs[i:i + 1], (128, 1)))
             for i in range(xq.shape[0])]
    for a, s in tiles[:1]:  # compile the (128, K) traces untimed
        jax.block_until_ready(f_scan(a, s))
        jax.block_until_ready(f_while(a, s))
    us_scan1, us_while1 = best_pair(
        lambda: [jax.block_until_ready(f_scan(a, s)) for a, s in tiles],
        lambda: [jax.block_until_ready(f_while(a, s)) for a, s in tiles],
        n=4)
    ee_saved1 = 1.0 - us_while1 / us_scan1
    emit("progressive_vgg16_head_early_exit_per_image", us_while1,
         f"scan_us={us_scan1:.1f} mean_exit={mean_exit:.2f}/{n_levels - 1} "
         f"wallclock_saved={ee_saved1 * 100:.0f}%")

    # decisive-margin head: a prototype classifier whose logit margins
    # clear the tail bound around mid-stream (exit ~3-4 of 6) — shows the
    # early-exit win scaling with the margin regime (the VGG head above
    # decides at 5/6, so it can only ever skip one of seven levels).
    # Own rng: the shared stream feeds the pre-existing random-head
    # trajectory row below, which must stay draw-for-draw comparable
    # across commits.
    from repro.models.protohead import prototype_head

    dk, dclasses, drows = 2048, 64, 256
    dxq, dxs, dw_q, _ = prototype_head(np.random.default_rng(42), dk,
                                       dclasses, drows, cfg=cfg)
    g_scan = jax.jit(lambda a, s: streaming_argmax(
        a, dw_q.q, s, dw_q.scale, cfg.n_bits, cfg.log2_radix)[1:])
    g_while = jax.jit(lambda a, s: streaming_argmax(
        a, dw_q.q, s, dw_q.scale, cfg.n_bits, cfg.log2_radix,
        early_exit=True)[1:])
    (dtok_s, dlv_s) = jax.tree.map(np.asarray, g_scan(dxq, dxs))
    (dtok_w, dlv_w) = jax.tree.map(np.asarray, g_while(dxq, dxs))
    assert (dtok_s == dtok_w).all() and (dlv_s == dlv_w).all()
    us_dscan, us_dwhile = best_pair(
        lambda: jax.block_until_ready(g_scan(dxq, dxs)),
        lambda: jax.block_until_ready(g_while(dxq, dxs)), n=10)
    d_saved = 1.0 - us_dwhile / us_dscan
    emit("progressive_decisive_head_early_exit_scan", us_dwhile,
         f"scan_us={us_dscan:.1f} batch_exit_level={int(dlv_w.max())}/"
         f"{n_levels - 1} mean_exit={float(dlv_w.mean()):.2f} "
         f"wallclock_saved={d_saved * 100:.0f}%")

    # decode-step weight-stack cache: the streamed head with the
    # load-time window-padded RHS plane stack (prepare_params prestack)
    # vs per-step weight plane extraction + window padding — decisions
    # verified identical before timing.  Decode-shaped operands (small
    # batch x large vocab): the per-step operand prep scales with the
    # WEIGHT, the GEMM with the batch, so this is the regime the cache
    # targets.  The stack is a jit ARGUMENT (as in serving, where it
    # lives in the params tree), not a baked closure constant.
    from repro.core.quant import PlaneOperands

    hk, hv, hm = 2048, 2048, 8  # decode: 8 slots, 2k hidden, 2k vocab
    hrng = np.random.default_rng(43)
    hxq = jnp.asarray(hrng.integers(-128, 128, (hm, hk), dtype=np.int8))
    hxs = jnp.asarray(hrng.uniform(0.01, 0.02, (hm, 1)).astype(np.float32))
    hwq = jnp.asarray(hrng.integers(-128, 128, (hk, hv), dtype=np.int8))
    hws = jnp.asarray(hrng.uniform(0.01, 0.02, (1, hv)).astype(np.float32))
    h_planes = PlaneOperands.prepare_rhs(hwq, cfg.n_bits, cfg.log2_radix,
                                         window_pad=True)
    h_step = jax.jit(lambda a, s, w: streaming_argmax(
        a, w, s, hws, cfg.n_bits, cfg.log2_radix)[1:])
    (htok_i, hlv_i) = jax.tree.map(np.asarray, h_step(hxq, hxs, hwq))
    (htok_c, hlv_c) = jax.tree.map(np.asarray, h_step(hxq, hxs, h_planes))
    assert (htok_i == htok_c).all() and (hlv_i == hlv_c).all()
    us_draw, us_dcache = best_pair(
        lambda: jax.block_until_ready(h_step(hxq, hxs, hwq)),
        lambda: jax.block_until_ready(h_step(hxq, hxs, h_planes)), n=10)
    c_saved = 1.0 - us_dcache / us_draw
    emit("progressive_decode_head_weight_stack_cache", us_dcache,
         f"inline_us={us_draw:.1f} wallclock_saved={c_saved * 100:.0f}% "
         f"batch={hm} k={hk} vocab={hv} (per-step weight plane "
         f"extraction amortized to load time)")

    # random classifier heads (the old online_* setting) for the JSON
    # trajectory: margins come from genuine top-order statistics
    from repro.core.progressive import (earliest_decision_level,
                                        progressive_matmul)
    rows = [{
        "name": "vgg16_logit_head", "n_levels": n_levels,
        "mean_exit_level": mean_exit, "exit_level_hist": hist,
        "early_exit_frac": float((lv < n_levels - 1).mean()),
        "prototype_accuracy": acc, "images": int(lv.size),
        "head_full_us": us_full, "head_truncated_us": us_trunc,
        "truncated_levels": trunc,
        "wallclock_saved_frac": saved,
    }, {
        # the early-exit WHILE scan: runtime-discovered exit, decisions
        # verified identical to the fixed scan before timing
        "name": "vgg16_logit_head_early_exit_scan", "n_levels": n_levels,
        "batch": {
            "scan_us": us_scan, "early_exit_us": us_while,
            "exit_level": int(lv.max()),
            "wallclock_saved_frac": ee_saved,
        },
        "per_image": {
            "scan_us": us_scan1, "early_exit_us": us_while1,
            "mean_exit_level": mean_exit,
            "wallclock_saved_frac": ee_saved1,
        },
    }, {
        "name": "decisive_head_early_exit_scan", "n_levels": n_levels,
        "k": dk, "classes": dclasses, "rows": drows,
        "scan_us": us_dscan, "early_exit_us": us_dwhile,
        "batch_exit_level": int(dlv_w.max()),
        "mean_exit_level": float(dlv_w.mean()),
        "wallclock_saved_frac": d_saved,
    }, {
        # per-decode-step operand amortization: cached window-padded RHS
        # plane stack vs per-step extraction, decisions identical
        "name": "decode_head_weight_stack_cache", "n_levels": n_levels,
        "k": hk, "vocab": hv, "batch": hm,
        "inline_us": us_draw, "cached_stack_us": us_dcache,
        "wallclock_saved_frac": c_saved,
    }]
    # multi-device consensus walk rows (virtual-device subprocess)
    progressive_sharded_bench(rows)
    # per-request precision classes: the calibrated policy frontier
    precision_policy_bench(rows)
    a = jnp.asarray(rng.integers(-128, 128, (256, 64), dtype=np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (64, 32), dtype=np.int8))
    res = progressive_matmul(a, b)
    rlv = np.asarray(earliest_decision_level(res))
    rows.append({
        "name": "random_head_256x64x32", "n_levels": int(res.partial.shape[0]),
        "mean_exit_level": float(rlv.mean()),
        "exit_level_hist": np.bincount(
            rlv, minlength=res.partial.shape[0]).tolist(),
        "early_exit_frac": float((rlv < res.partial.shape[0] - 1).mean()),
    })
    if json_path:
        payload = {
            "bench": "progressive_streaming",
            "host_backend": jax.default_backend(),
            "note": "vgg16 head is prototype-calibrated (random-init "
                    "margins are ~0 by construction; trained classifiers "
                    "operate in the decisive-margin regime measured here)",
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        emit("progressive_json", 0.0, f"wrote={json_path}")


# Body of the multi-device bench subprocess: a decode-head-shaped
# streaming argmax, single-device vs the shard_mapped consensus walk on
# local (data, model) meshes.  Decisions and exit levels are verified
# bit-exact (scan AND early-exit while) before any timing.  Shapes,
# repetition counts, and the mesh list are prepended by the caller.
SHARDED_BENCH_BODY = r"""
import json
import sys
import time
sys.path.insert(0, "src")
import numpy as np
import jax, jax.numpy as jnp
from repro.core.progressive import streaming_argmax
from repro.launch.mesh import make_local_mesh

rng = np.random.default_rng(43)
xq = jnp.asarray(rng.integers(-128, 128, (B, K), dtype=np.int8))
xs = jnp.asarray(rng.uniform(0.01, 0.02, (B, 1)).astype(np.float32))
wq = jnp.asarray(rng.integers(-128, 128, (K, V), dtype=np.int8))
ws = jnp.asarray(rng.uniform(0.01, 0.02, (1, V)).astype(np.float32))


def timeit(fn):
    t0 = time.perf_counter()
    for _ in range(REPS):
        fn()
    return (time.perf_counter() - t0) / REPS * 1e6


f_single = jax.jit(lambda a, s: streaming_argmax(a, wq, s, ws)[1:])
ref = jax.tree.map(np.asarray, f_single(xq, xs))
rows = []
for name in MESHES:
    d, m = (int(t) for t in name.split("x"))
    mesh = make_local_mesh(d, m)
    f_sh = jax.jit(lambda a, s, mesh=mesh: streaming_argmax(
        a, wq, s, ws, mesh=mesh)[1:])
    got = jax.tree.map(np.asarray, f_sh(xq, xs))
    exact = all(bool((np.asarray(a) == np.asarray(b)).all())
                for a, b in zip(ref, got))
    f_ee = jax.jit(lambda a, s, mesh=mesh: streaming_argmax(
        a, wq, s, ws, mesh=mesh, early_exit=True)[1:])
    got_ee = jax.tree.map(np.asarray, f_ee(xq, xs))
    exact_ee = all(bool((np.asarray(a) == np.asarray(b)).all())
                   for a, b in zip(ref, got_ee))
    # parity is the precondition of the timing claim: fail the bench
    # loudly instead of shipping a non-bit-exact row
    assert exact and exact_ee, (
        f"sharded walk lost bit-parity on mesh {name}: "
        f"scan={exact} early_exit={exact_ee}")
    best_s = best_m = float("inf")
    for _ in range(ROUNDS):  # interleaved min-of-rounds
        best_s = min(best_s,
                     timeit(lambda: jax.block_until_ready(f_single(xq, xs))))
        best_m = min(best_m,
                     timeit(lambda: jax.block_until_ready(f_sh(xq, xs))))
    rows.append(dict(
        name="sharded_decode_head_" + name, mesh=name, batch=B, k=K,
        vocab=V, devices=d * m, single_us=best_s, sharded_us=best_m,
        speedup=best_s / best_m, bit_exact=exact,
        early_exit_bit_exact=exact_ee,
        note="host-platform virtual devices share one CPU: this measures "
             "partitioning overhead, not parallel scaling"))
print("JSON:" + json.dumps(rows))
"""


def progressive_sharded_bench(rows: list):
    """Multi-device consensus head walk -> progressive_sharded_* rows.

    Runs in a subprocess with 8 virtual host-platform devices (the
    XLA device-count flag is consumed at jax init, so this process
    cannot grow devices itself).  Each row records the single-device
    streaming argmax vs the shard_mapped walk on a (data, model) local
    mesh — tokens/exit levels verified bit-exact (both control flows)
    before timing.  CHECK_MODE trims shapes, meshes, and repetitions.
    """
    import json
    import subprocess

    from repro.launch.mesh import virtual_device_env

    b, k, v = (4, 256, 512) if CHECK_MODE else (8, 2048, 2048)
    reps, rounds = (1, 1) if CHECK_MODE else (10, 3)
    meshes = ["1x2"] if CHECK_MODE else ["1x2", "1x4", "2x4"]
    header = (f"B, K, V = {b}, {k}, {v}\n"
              f"REPS, ROUNDS = {reps}, {rounds}\n"
              f"MESHES = {meshes!r}\n")
    out = subprocess.run(
        [sys.executable, "-c", header + SHARDED_BENCH_BODY],
        capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=virtual_device_env(8), timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded bench subprocess failed:\n{out.stderr[-3000:]}")
    payload = [ln for ln in out.stdout.splitlines()
               if ln.startswith("JSON:")][-1]
    new_rows = json.loads(payload[len("JSON:"):])
    for r in new_rows:
        emit(f"progressive_{r['name']}", r["sharded_us"],
             f"single_us={r['single_us']:.1f} speedup={r['speedup']:.2f}x "
             f"devices={r['devices']} bit_exact={r['bit_exact']} "
             f"early_exit_bit_exact={r['early_exit_bit_exact']}")
    rows.extend(new_rows)


def serving_bench(json_path: str | None = None):
    """Gateway serving under synthetic Poisson traffic -> serving_* rows
    + BENCH_serving.json.

    The smoke LM serves a mixed-prompt-length request trace through
    `ServingGateway` (bucketed AOT prefill, donated decode state, async
    emit) with the Poisson arrival process replayed in REAL time
    (`run(realtime=True)` honors the pre-stamped `t_arrival` instants),
    so TTFT includes genuine queueing delay.  Measured per mode:
    tokens/s and p50/p99 time-to-first-token / per-output-token
    latency, with MSDF early exit ON vs OFF — the paper's saved
    significance levels showing up as saved fleet latency.  Before any
    timing, the gateway's output streams are asserted bit-identical to
    the plain `ContinuousBatcher` serving the same request set (both
    early-exit modes commit identical tokens by construction).
    CHECK_MODE trims requests, slots, and generation lengths.
    """
    import dataclasses as _dc
    import json
    import time

    from repro.configs import get_smoke
    from repro.core.quant import QuantConfig
    from repro.models.common import materialize
    from repro.models.transformer import lm_build
    from repro.serve import ContinuousBatcher, Request, ServingGateway
    from repro.serve.engine import prepare_params

    cfg = _dc.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
    params = prepare_params(cfg, materialize(lm_build(cfg),
                                             jax.random.PRNGKey(0)))
    if CHECK_MODE:
        n_req, n_slots, max_len, max_new, group = 6, 2, 32, 4, 2
        mean_gap = 0.005
    else:
        n_req, n_slots, max_len, max_new, group = 48, 8, 64, 16, 4
        mean_gap = 0.02
    rng = np.random.default_rng(7)
    lens = rng.integers(3, max_len - max_new, n_req)  # spans the buckets
    prompts = [rng.integers(0, cfg.vocab, (int(L),)).astype(np.int32)
               for L in lens]
    gaps = rng.exponential(mean_gap, n_req)  # one trace, replayed per mode
    offsets = np.cumsum(gaps)

    def make_reqs():
        return [Request(uid=i, prompt=p, max_new_tokens=max_new)
                for i, p in enumerate(prompts)]

    # bit-parity reference: the plain batcher, same request set
    ref = make_reqs()
    eng = ContinuousBatcher(cfg, params, n_slots=n_slots, max_len=max_len,
                            progressive=True, early_exit=True)
    for r in ref:
        eng.submit(r)
    eng.run(max_steps=100_000)

    rows = []
    for ee in (True, False):
        reqs = make_reqs()
        gw = ServingGateway(cfg, params, n_slots=n_slots, max_len=max_len,
                            progressive=True, early_exit=ee,
                            prefill_group=group)
        # stamp arrivals AFTER construction: AOT warmup is startup cost,
        # not queueing delay
        t0 = time.perf_counter() + 0.01
        for r, dt in zip(reqs, offsets):
            r.t_arrival = t0 + float(dt)
            gw.submit(r)
        gw.run(realtime=True)
        gw.close()
        st = gw.stats()
        for a, b in zip(ref, reqs):
            assert a.output == b.output, \
                ("gateway/batcher token divergence", ee, a.uid)
        mode = "on" if ee else "off"
        emit(f"serving_gateway_early_exit_{mode}",
             st["tpot_p50_s"] * 1e6,
             f"tok_s={st['tokens_per_s']:.1f} "
             f"ttft_p50_ms={st['ttft_p50_s'] * 1e3:.1f} "
             f"ttft_p99_ms={st['ttft_p99_s'] * 1e3:.1f} "
             f"tpot_p99_ms={st['tpot_p99_s'] * 1e3:.1f} "
             f"reqs={n_req} slots={n_slots} "
             f"mean_exit={st['mean_exit_level']:.2f}/{st['n_levels'] - 1}")
        rows.append({
            "name": f"poisson_early_exit_{mode}",
            "early_exit": ee,
            "requests": n_req, "n_slots": n_slots, "max_len": max_len,
            "max_new_tokens": max_new, "prefill_group": group,
            "buckets": st["buckets"],
            "prompt_len_min": int(lens.min()),
            "prompt_len_max": int(lens.max()),
            "mean_interarrival_s": mean_gap,
            "tokens": st["tokens"], "completed": st["completed"],
            "decode_steps": st["steps"], "prefill_dispatches": st["prefills"],
            "tokens_per_s": st["tokens_per_s"],
            "ttft_p50_s": st["ttft_p50_s"], "ttft_p99_s": st["ttft_p99_s"],
            "tpot_p50_s": st["tpot_p50_s"], "tpot_p99_s": st["tpot_p99_s"],
            "n_levels": st["n_levels"],
            "mean_exit_level": st["mean_exit_level"],
            "mean_levels_saved": st["mean_levels_saved"],
            "bit_identical_to_batcher": True,
        })
    if json_path:
        payload = {
            "bench": "serving_gateway",
            "host_backend": jax.default_backend(),
            "model": "smollm-135m (smoke)",
            "note": "Poisson arrivals replayed in real time; TTFT "
                    "includes queueing delay.  Gateway output asserted "
                    "bit-identical to the plain ContinuousBatcher for "
                    "the same request set before timing.",
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        emit("serving_json", 0.0, f"wrote={json_path}")


def attention_bench(json_path: str | None = None):
    """Digit-serial attention decode/prefill -> attention_* rows +
    BENCH_attention.json.

    One KV cache, four decode modes: the float oracle; quantized QK^T
    that re-quantizes + re-extracts K planes from the float cache every
    step (what decode costs WITHOUT the incremental stack); the
    incrementally plane-stacked cache (extraction already paid at append
    time); and margin-bounded early exit on top of the plane cache.
    Parity is asserted before any timing: plane-cache scores are
    bit-identical to re-extraction, early exit at tight tolerance is
    bit-identical to full depth, and the quantized output tracks the
    float oracle to W8A8 noise.  The flash-fused level-walk kernel runs
    as an interpret-mode correctness row (never timed off-TPU).
    CHECK_MODE trims shapes.
    """
    import json

    from repro.core.quant import QuantConfig
    from repro.models.attention import (chunked_attention, decode_attention,
                                        init_kv_cache, update_kv_cache)

    cfg = QuantConfig()
    if CHECK_MODE:
        b, length, kvh, g, dh, sq = 2, 64, 2, 2, 32, 32
    else:
        b, length, kvh, g, dh, sq = 4, 512, 4, 2, 64, 256
    h = kvh * g
    rng = np.random.default_rng(11)
    cache = init_kv_cache(b, length, kvh, dh, jnp.float32, quant=cfg)
    ks = jnp.asarray(rng.standard_normal((b, length, kvh, dh)), jnp.float32)
    vs = jnp.asarray(rng.standard_normal((b, length, kvh, dh)), jnp.float32)
    pos = jnp.asarray(np.tile(np.arange(length), (b, 1)), jnp.int32)
    cache = update_kv_cache(cache, ks, vs, pos, quant=cfg)
    q = jnp.asarray(rng.standard_normal((b, 1, h, dh)), jnp.float32)
    qpos = jnp.full((b,), length - 1, jnp.int32)

    fns = {
        "float": lambda q, c: decode_attention(
            q, c.k, c.v, c.positions, qpos),
        "quant_reextract": lambda q, c: decode_attention(
            q, c.k, c.v, c.positions, qpos, l2r=cfg),
        "plane_cache": lambda q, c: decode_attention(
            q, c.k, c.v, c.positions, qpos, l2r=cfg,
            k_planes=c.k_planes, k_scale=c.k_scale),
        "early_exit": lambda q, c: decode_attention(
            q, c.k, c.v, c.positions, qpos, l2r=cfg,
            k_planes=c.k_planes, k_scale=c.k_scale,
            early_exit=True, exit_tol=1e-4),
    }
    # parity gates the timing.  Bit-exactness is asserted on eager
    # (op-by-op) execution — identical int scores and scales make every
    # downstream float op identical; the jitted closures are different
    # XLA graphs, whose fusion may reassociate the f32 epilogue by an
    # ulp, so they get an ulp-level tolerance instead.
    eag = {name: np.asarray(fn(q, cache)) for name, fn in fns.items()}
    np.testing.assert_array_equal(eag["quant_reextract"],
                                  eag["plane_cache"])
    np.testing.assert_array_equal(eag["plane_cache"], eag["early_exit"])
    np.testing.assert_allclose(eag["plane_cache"], eag["float"], atol=0.1)
    modes = {name: jax.jit(fn) for name, fn in fns.items()}
    out = {name: jax.block_until_ready(fn(q, cache))
           for name, fn in modes.items()}
    for name in ("quant_reextract", "early_exit"):
        np.testing.assert_allclose(out[name], out["plane_cache"], atol=2e-6)
    np.testing.assert_allclose(np.asarray(out["plane_cache"]),
                               np.asarray(out["float"]), atol=0.1)

    n_it = 1 if CHECK_MODE else 20
    rounds = 1 if CHECK_MODE else 3
    best = {name: float("inf") for name in modes}
    for _ in range(rounds):  # interleaved min-of-rounds (shared host)
        for name, fn in modes.items():
            best[name] = min(best[name], _timeit(
                lambda fn=fn: jax.block_until_ready(fn(q, cache)), n=n_it,
                warmup=0))
    rows = []
    for name, us in best.items():
        emit(f"attention_decode_{name}", us,
             f"b={b} len={length} kv={kvh} g={g} dh={dh} "
             f"vs_float={best['float'] / us:.2f}x")
        rows.append({"name": f"decode_{name}", "us_per_step": us,
                     "batch": b, "cache_len": length, "kv_heads": kvh,
                     "group": g, "head_dim": dh,
                     "speedup_vs_float": best["float"] / us})

    # chunked prefill: float vs quantized (plane extraction once per call)
    qp = jnp.asarray(rng.standard_normal((b, sq, h, dh)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((b, sq, kvh, dh)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((b, sq, kvh, dh)), jnp.float32)
    qc_ = min(96, sq)
    kc_ = min(64, sq)
    pf_f = jax.jit(lambda a, b_, c: chunked_attention(
        a, b_, c, q_chunk=qc_, kv_chunk=kc_))
    pf_q = jax.jit(lambda a, b_, c: chunked_attention(
        a, b_, c, q_chunk=qc_, kv_chunk=kc_, l2r=cfg))
    o_f = jax.block_until_ready(pf_f(qp, kp, vp))
    o_q = jax.block_until_ready(pf_q(qp, kp, vp))
    np.testing.assert_allclose(np.asarray(o_q), np.asarray(o_f), atol=0.15)
    us_f, us_q = _best_pair(
        lambda: jax.block_until_ready(pf_f(qp, kp, vp)),
        lambda: jax.block_until_ready(pf_q(qp, kp, vp)), n=max(1, n_it // 4))
    emit("attention_prefill_float", us_f, f"b={b} sq={sq} h={h} dh={dh}")
    emit("attention_prefill_quant", us_q,
         f"b={b} sq={sq} h={h} dh={dh} vs_float={us_f / us_q:.2f}x")
    rows.append({"name": "prefill_float", "us_per_call": us_f,
                 "batch": b, "seq": sq, "heads": h, "head_dim": dh})
    rows.append({"name": "prefill_quant", "us_per_call": us_q,
                 "batch": b, "seq": sq, "heads": h, "head_dim": dh,
                 "speedup_vs_float": us_f / us_q})

    # flash-fused level walk: interpret-mode correctness (tiny — the
    # interpreter is orders of magnitude off any timing signal)
    from repro.kernels.flash_attention import flash_attention_l2r_pallas
    from repro.kernels.flash_attention.ref import attention_ref
    sb = 16
    qs_ = qp[:1, :sb]
    ks_ = kp[:1, :sb]
    vs_ = vp[:1, :sb]
    o_ker = flash_attention_l2r_pallas(qs_, ks_, vs_, bq=8, bkv=8,
                                       interpret=True)
    o_ref = attention_ref(qs_, ks_, vs_, True, None, None)
    err = float(jnp.max(jnp.abs(o_ker - o_ref)))
    assert err < 0.1, err  # W8A8 score noise only
    emit("attention_flash_l2r_interpret", "n/a",
         f"sq={sb} max_err_vs_float={err:.3e} validated=True")
    rows.append({"name": "flash_l2r_interpret", "seq": sb,
                 "max_err_vs_float_ref": err, "validated": True})

    # roofline accounting: bytes a decode step must move, per mode —
    # the model the measured decode rows should be judged against
    from repro.launch.roofline import attn_decode_step_bytes
    acct = attn_decode_step_bytes(b, length, kvh, dh,
                                  n_bits=cfg.n_bits,
                                  log2_radix=cfg.log2_radix,
                                  kv_dtype_bytes=4,  # f32 cache above
                                  levels=2)  # early-decided walk depth
    emit("attention_roofline_bytes", "n/a",
         f"plane_cache_vs_float={acct['plane_cache_vs_float']:.2f}x "
         f"truncated_vs_plane_cache="
         f"{acct['truncated_vs_plane_cache']:.2f}x")
    rows.append({"name": "roofline_decode_bytes", **acct})

    if json_path:
        payload = {
            "bench": "l2r_attention",
            "host_backend": jax.default_backend(),
            "note": "Decode modes share one KV cache; plane-cache scores "
                    "asserted bit-identical to per-step re-extraction and "
                    "early exit bit-identical to full depth before "
                    "timing.  On a CPU host the digit-serial walk is ~D "
                    "integer GEMVs vs one fused float GEMV, so quantized "
                    "rows trail the float oracle in wall-clock; the "
                    "apples-to-apples number is plane_cache vs "
                    "quant_reextract (the per-step extraction the "
                    "incremental stack removes) plus the roofline bytes "
                    "row.  Flash-fused kernel is interpret-validated, "
                    "not timed, off-TPU.",
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        emit("attention_json", 0.0, f"wrote={json_path}")


def main(argv=None) -> None:
    import argparse

    global CHECK_MODE
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="smoke mode: 1 repetition, no warmup, JSON "
                         "records land in a temp dir (exercises every "
                         "bench path in CI without overwriting the "
                         "checked-in trajectory files)")
    ap.add_argument("--json-dir", default=None,
                    help="directory for the BENCH_*.json records "
                         "(default: the benchmarks dir, or a temp dir "
                         "under --check; CI passes an artifact dir so "
                         "the per-run JSONs are uploadable)")
    args = ap.parse_args(argv)
    CHECK_MODE = args.check
    if args.json_dir:
        json_dir = args.json_dir
        os.makedirs(json_dir, exist_ok=True)
    elif args.check:
        import tempfile
        json_dir = tempfile.mkdtemp(prefix="bench_check_")
    else:
        json_dir = os.path.dirname(__file__)
    print("name,us_per_call,derived")
    table1()
    table2()
    vgg16_cycles()
    kernel_bench()
    kernel_stacked_bench(os.path.join(json_dir, "BENCH_l2r_gemm.json"))
    ipu_bench()
    online_stats()
    progressive_bench(os.path.join(json_dir, "BENCH_progressive.json"))
    attention_bench(os.path.join(json_dir, "BENCH_attention.json"))
    serving_bench(os.path.join(json_dir, "BENCH_serving.json"))


if __name__ == "__main__":
    main()
