"""Project the roofline effect of the flash-attention Pallas kernel.

The kernel (src/repro/kernels/flash_attention/, validated interpret=True)
cannot be compiled for TPU in this CPU-only container, so its effect on a
cell's memory term is PROJECTED from the archived compiled HLO:

  memory'_bytes = memory_bytes
                  - (identified attention score-block traffic)
                  + (kernel surface traffic: Q, K, V, O once per layer)

Score-block traffic is identified in the HLO as (a) dot ops whose
op_name metadata carries the attention einsum signatures
(bqkgd,bskd->bkgqs / bkgqs,bskd->bkgqd) — charged operands+result like
the analyzer does — and (b) fusions with ndim>=4 whose trailing two dims
are both >= 1024 (the materialized score/softmax blocks).  Kernel
surface traffic is analytic from the architecture (bf16).

    PYTHONPATH=src python -m benchmarks.flash_projection \
        --cell phi3-medium-14b_prefill_32k_1pod
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

import zstandard

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch import hlo_analysis as H  # noqa: E402
from repro.launch.roofline import roofline_terms  # noqa: E402

_ATTN_SIGS = ("bqkgd,bskd->bkgqs", "bkgqs,bskd->bkgqd")


def _multipliers(comps):
    entry = next(c for c in comps.values() if c["entry"])
    mult = {entry["name"]: 1.0}
    order = [entry["name"]]
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        c = comps.get(name)
        if not c:
            continue
        for iname, rhs in c["instrs"]:
            kind = H._op_kind(rhs)
            m_, ch = 1.0, []
            if kind == "while":
                t = H._TRIP_RE.search(rhs)
                m_ = float(t.group(1)) if t else 1.0
                for key in ("body", "condition"):
                    mm = re.search(key + r"=%([\w\.\-]+)", rhs)
                    if mm:
                        ch.append(mm.group(1))
            elif kind == "call":
                mm = re.search(r"to_apply=%([\w\.\-]+)", rhs)
                if mm:
                    ch.append(mm.group(1))
            for c2 in ch:
                mult[c2] = mult.get(c2, 0) + mult[name] * m_
                if c2 not in order:
                    order.append(c2)
    return mult


def score_traffic_bytes(hlo: str) -> float:
    comps = H.parse_module(hlo)
    mult = _multipliers(comps)
    total = 0.0
    for name, c in comps.items():
        m_ = mult.get(name, 0)
        if not m_:
            continue
        for iname, rhs in c["instrs"]:
            kind = H._op_kind(rhs)
            if kind == "dot" and any(s in rhs for s in _ATTN_SIGS):
                b = H._shape_bytes(c["defs"][iname])
                for opm in re.finditer(r"dot\(%([\w\.\-]+),\s*%([\w\.\-]+)\)", rhs):
                    for nm in opm.groups():
                        b += H._storage_bytes(nm, c)
                total += m_ * b
            elif kind == "fusion":
                dims = H._shape_dims(c["defs"][iname])
                if len(dims) >= 4 and len(dims) >= 2 \
                        and dims[-1] >= 1024 and dims[-2] >= 1024:
                    total += m_ * 2.0 * H._shape_bytes(c["defs"][iname])
    return total


def kernel_surface_bytes(arch: str, shape: str, chips: int) -> float:
    cfg = get_config(arch)
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    per_layer = 2 * (  # read q/k/v + write o, bf16
        b * s * cfg.n_heads * cfg.head_dim  # q
        + 2 * b * s * cfg.n_kv * cfg.head_dim  # k, v
        + b * s * cfg.n_heads * cfg.head_dim  # o
    )
    n_attn = sum(1 for k in cfg.mixer_kinds() if k in ("global", "local"))
    factor = 1 if sp.kind != "train" else 3  # fwd + remat fwd + bwd reads
    return per_layer * n_attn * factor / chips


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    rec = json.load(open(os.path.join(args.dir, args.cell + ".json")))
    hlo = zstandard.ZstdDecompressor().decompress(
        open(os.path.join(args.dir, args.cell + ".hlo.zst"), "rb").read()
    ).decode()
    score_b = score_traffic_bytes(hlo)
    surf_b = kernel_surface_bytes(rec["arch"], rec["shape"], rec["chips"])
    rl = rec["roofline"]
    new_bytes = rl["bytes_hbm"] - score_b + surf_b
    new = roofline_terms(rl["flops"], new_bytes, rl["wire_bytes"], rec["chips"])
    print(f"cell: {args.cell}")
    print(f"  identified score traffic : {score_b/1e9:10.2f} GB/chip "
          f"({score_b/rl['bytes_hbm']*100:.0f}% of memory bytes)")
    print(f"  kernel surface traffic   : {surf_b/1e9:10.2f} GB/chip")
    print(f"  memory term              : {rl['memory_s']:8.2f}s -> {new.memory_s:8.2f}s")
    print(f"  bound                    : {rl['bound_s']:8.2f}s -> {new.bound_s:8.2f}s "
          f"(dominant: {rl['dominant']} -> {new.dominant})")
    out = dict(rec)
    out["roofline_flash_projection"] = new.asdict()
    out["flash_projection"] = {"score_bytes": score_b, "surface_bytes": surf_b}
    with open(os.path.join(args.dir, args.cell + "_flashproj.json"), "w") as fh:
        json.dump(out, fh, indent=1)


if __name__ == "__main__":
    main()
