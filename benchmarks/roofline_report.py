"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dry-run artifacts (artifacts/dryrun/*.json).

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCHS, SHAPES, cell_supported  # noqa: E402


def load(dir_: str):
    recs = {}
    for p in glob.glob(os.path.join(dir_, "*.json")):
        r = json.load(open(p))
        key = (r["arch"], r["shape"], "2pod" if r["multi_pod"] else "1pod",
               r.get("l2r", False), os.path.basename(p))
        recs[key] = r
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:7.2f}s "
    return f"{x*1e3:7.2f}ms"


def roofline_table(recs, pod="1pod", tag_filter=lambda name: "_opt" not in name
                   and "_l2r" not in name):
    rows = []
    for arch in ARCHS:
        for shape in SHAPES:
            ok, why = cell_supported(arch, shape)
            if not ok:
                rows.append((arch, shape, None, why))
                continue
            cands = [r for (a, s, p, l2r, name), r in recs.items()
                     if a == arch and s == shape and p == pod and not l2r
                     and tag_filter(name)]
            rows.append((arch, shape, cands[0] if cands else None, ""))
    return rows


def print_roofline(recs, pod="1pod", file=sys.stdout):
    w = file.write
    w(f"| arch | shape | compute | memory | collective | dominant | "
      f"bound | useful (6ND/HLO) | note |\n")
    w("|---|---|---|---|---|---|---|---|---|\n")
    for arch, shape, r, why in roofline_table(recs, pod):
        if r is None:
            w(f"| {arch} | {shape} | — | — | — | — | — | — | SKIP: {why[:60]}… |\n")
            continue
        rl = r["roofline"]
        ucr = r.get("useful_compute_ratio")
        w(f"| {arch} | {shape} | {fmt_s(rl['compute_s'])} | {fmt_s(rl['memory_s'])} "
          f"| {fmt_s(rl['collective_s'])} | **{rl['dominant']}** | "
          f"{fmt_s(rl['bound_s'])} | {ucr:.3f} | |\n" if ucr is not None else
          f"| {arch} | {shape} | {fmt_s(rl['compute_s'])} | {fmt_s(rl['memory_s'])} "
          f"| {fmt_s(rl['collective_s'])} | **{rl['dominant']}** | "
          f"{fmt_s(rl['bound_s'])} | n/a | |\n")


def print_dryrun(recs, file=sys.stdout):
    w = file.write
    w("| arch | shape | mesh | chips | compile_s | HLO GFLOP/chip | "
      "HBM GB/chip | wire GB/chip | mem analysis temp GB |\n")
    w("|---|---|---|---|---|---|---|---|---|\n")
    for (a, s, p, l2r, name), r in sorted(recs.items()):
        if l2r or "_opt" in name:
            continue
        rl = r["roofline"]
        tmp = r["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9
        w(f"| {a} | {s} | {p} | {r['chips']} | {r['compile_s']} | "
          f"{rl['flops']/1e9:.1f} | {rl['bytes_hbm']/1e9:.2f} | "
          f"{rl['wire_bytes']/1e9:.3f} | {tmp:.2f} |\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--pod", default="1pod")
    args = ap.parse_args()
    recs = load(args.dir)
    print(f"# Roofline ({args.pod}, {len(recs)} artifacts)\n")
    print_roofline(recs, args.pod)
    print("\n# Dry-run detail\n")
    print_dryrun(recs)


if __name__ == "__main__":
    main()
